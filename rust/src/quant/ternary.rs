//! eq. 6-14 + Algorithm 2 server step, in plain Rust.

use crate::model::ParamSet;

/// Layer-wise scale g: R^n -> [-1, 1] (eq. 6). Zero layers stay zero.
pub fn scale(theta: &[f32]) -> Vec<f32> {
    let m = theta.iter().fold(0f32, |acc, x| acc.max(x.abs()));
    if m <= f32::MIN_POSITIVE {
        return theta.to_vec();
    }
    theta.iter().map(|x| x / m).collect()
}

/// Delta = T * mean(|theta_s|) (eq. 8).
pub fn threshold_mean(theta_s: &[f32], t: f32) -> f32 {
    if theta_s.is_empty() {
        return 0.0;
    }
    let s: f64 = theta_s.iter().map(|x| x.abs() as f64).sum();
    t * (s / theta_s.len() as f64) as f32
}

/// Delta = T * max(|theta_s|) (eq. 7, TTQ heuristic).
pub fn threshold_max(theta_s: &[f32], t: f32) -> f32 {
    t * theta_s.iter().fold(0f32, |acc, x| acc.max(x.abs()))
}

/// Ternary sign pattern: sign(step(|x| - Delta) * x) in {-1, 0, +1} as i8.
pub fn ternarize(theta_s: &[f32], delta: f32) -> Vec<i8> {
    theta_s
        .iter()
        .map(|&x| {
            if x > delta {
                1
            } else if x < -delta {
                -1
            } else {
                0
            }
        })
        .collect()
}

/// Rebuild dense weights: theta_t = wq * it (eq. 12).
pub fn dequantize(it: &[i8], wq: f32) -> Vec<f32> {
    it.iter().map(|&s| wq * s as f32).collect()
}

/// Full FTTQ layer quantization: scale -> eq.8 threshold -> ternarize.
/// Returns (it, delta). Mirrors kernels.ref.fttq_quantize with wq folded out.
pub fn fttq_quantize(theta: &[f32], t: f32) -> (Vec<i8>, f32) {
    let s = scale(theta);
    let delta = threshold_mean(&s, t);
    (ternarize(&s, delta), delta)
}

/// eq. 20 optimal factor: mean of scaled weights over the positive support.
/// Used as the w^q re-estimate when rebuilding uploads server-side.
pub fn optimal_wq(theta_s: &[f32], delta: f32) -> f32 {
    let (mut sum, mut n) = (0f64, 0usize);
    for &x in theta_s {
        if x > delta {
            sum += x as f64;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64) as f32
    }
}

/// Server-side downstream step (Algorithm 2): normalize the aggregated
/// global layer, re-quantize with the fixed threshold, emit ternary {-1,0,+1}.
pub fn server_requantize(theta: &[f32], fixed_delta: f32) -> Vec<i8> {
    let s = scale(theta);
    ternarize(&s, fixed_delta)
}

/// eq.-20 symmetric optimal factor: mean |theta| over the ternary support —
/// the scale that minimizes ||theta - w*it||_2 for a fixed pattern.
pub fn optimal_wq_symmetric(theta: &[f32], it: &[i8]) -> f32 {
    let (mut sum, mut n) = (0f64, 0usize);
    for (&x, &s) in theta.iter().zip(it) {
        if s != 0 {
            sum += x.abs() as f64;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64) as f32
    }
}

/// The 2-bit *inference* model for a ternary layer: pattern + eq.-20 scale.
///
/// Algorithm 2's downstream payload is the bare sign pattern; since client
/// FTTQ re-normalizes layer-wise (eq. 6), training is invariant to any
/// per-layer positive rescaling of the downloaded model — so the model the
/// paper *evaluates* (2-bit weights, Table II) is the pattern scaled by the
/// optimal factor, which the server can derive from the same aggregate.
pub fn requantize_scaled(theta: &[f32], fixed_delta: f32) -> (Vec<i8>, f32) {
    let s = scale(theta);
    let it = ternarize(&s, fixed_delta);
    // factor in *unscaled* units so the rebuilt layer approximates theta
    let wq = optimal_wq_symmetric(theta, &it);
    (it, wq)
}

/// Apply `server_requantize` to every *quantized* tensor of a ParamSet,
/// leaving biases untouched. Returns the ternary patterns per quantized
/// layer (the downstream payload) in quantized-index order.
pub fn requantize_paramset(
    params: &ParamSet,
    quantized_idx: &[usize],
    fixed_delta: f32,
) -> Vec<Vec<i8>> {
    quantized_idx
        .iter()
        .map(|&i| server_requantize(&params.tensors[i].data, fixed_delta))
        .collect()
}

/// Rebuild a broadcast global model from ternary patterns + the biases of
/// `base`: quantized tensors become the ternary values (as f32), biases are
/// copied from `base`. This is exactly what a client materializes after the
/// downstream message (Algorithm 2: download quantified theta^t).
pub fn rebuild_from_ternary(
    base: &ParamSet,
    quantized_idx: &[usize],
    patterns: &[Vec<i8>],
) -> ParamSet {
    let mut out = base.clone();
    for (k, &i) in quantized_idx.iter().enumerate() {
        let t = &mut out.tensors[i];
        debug_assert_eq!(t.data.len(), patterns[k].len());
        for (x, &s) in t.data.iter_mut().zip(&patterns[k]) {
            *x = s as f32;
        }
    }
    out
}

/// Sparsity of a ternary pattern (fraction of zeros).
pub fn sparsity(it: &[i8]) -> f64 {
    if it.is_empty() {
        return 0.0;
    }
    it.iter().filter(|&&s| s == 0).count() as f64 / it.len() as f64
}

/// Quantization error ||theta - wq*it||_2 (eq. 3 objective, diagnostics).
pub fn quant_error(theta: &[f32], it: &[i8], wq: f32) -> f64 {
    theta
        .iter()
        .zip(it)
        .map(|(&x, &s)| {
            let d = (x - wq * s as f32) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::forall;

    #[test]
    fn scale_maps_to_unit_interval() {
        let v = vec![-4.0, 2.0, 1.0];
        let s = scale(&v);
        assert_eq!(s, vec![-1.0, 0.5, 0.25]);
        assert_eq!(scale(&[0.0, 0.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn eq9_threshold_bounded_by_tk() {
        forall(64, |rng| {
            let n = 1 + rng.below(500) as usize;
            let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let s = scale(&v);
            let t = rng.next_f32();
            assert!(threshold_mean(&s, t) <= t + 1e-6);
        });
    }

    #[test]
    fn ternarize_boundaries() {
        let v = vec![0.5, -0.5, 0.2, -0.2, 0.0, 0.200001];
        assert_eq!(ternarize(&v, 0.2), vec![1, -1, 0, 0, 0, 1]);
    }

    #[test]
    fn dequantize_roundtrip() {
        let it = vec![1i8, -1, 0, 1];
        assert_eq!(dequantize(&it, 0.5), vec![0.5, -0.5, 0.0, 0.5]);
    }

    #[test]
    fn optimal_wq_minimizes_error() {
        forall(32, |rng| {
            let v: Vec<f32> = (0..500).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let delta = 0.3;
            let it = ternarize(&v, delta);
            let w_star = optimal_wq(&v, delta);
            if w_star == 0.0 {
                return;
            }
            let e0 = quant_error(&v, &it, w_star);
            for eps in [0.01f32, 0.05, 0.2] {
                // positive-support error must not beat w*; full error uses
                // both supports so compare against the symmetric optimum:
                let e_hi = quant_error(&v, &it, w_star + eps);
                let e_lo = quant_error(&v, &it, w_star - eps);
                // w* is optimal for the positive support; for U(-1,1) the
                // negative optimum coincides (Prop 4.1), so perturbing by
                // eps should not improve by more than the asymmetry noise.
                assert!(e_hi + 1e-4 > e0 - 0.05 * e0);
                assert!(e_lo + 1e-4 > e0 - 0.05 * e0);
            }
        });
    }

    #[test]
    fn server_requantize_is_ternary() {
        forall(32, |rng| {
            let v: Vec<f32> = (0..200).map(|_| rng.normal()).collect();
            let it = server_requantize(&v, 0.05);
            assert!(it.iter().all(|&s| s == -1 || s == 0 || s == 1));
            // the largest-magnitude weight always survives the threshold
            let arg = v
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                .unwrap()
                .0;
            assert_ne!(it[arg], 0);
        });
    }

    #[test]
    fn fttq_quantize_matches_python_golden() {
        // Golden values computed by kernels/ref.py:
        //   theta = [0.4, -0.2, 0.05, 0.0, -0.9, 0.3], T = 0.5
        //   theta_s = theta / 0.9
        //   delta = 0.5 * mean(|theta_s|) = 0.5*(1.85/0.9/6) = 0.17129...
        let theta = [0.4, -0.2, 0.05, 0.0, -0.9, 0.3];
        let (it, delta) = fttq_quantize(&theta, 0.5);
        assert!((delta - 0.171296).abs() < 1e-5, "{delta}");
        assert_eq!(it, vec![1, -1, 0, 0, -1, 1]);
    }

    #[test]
    fn sparsity_and_error() {
        let it = vec![1i8, 0, 0, -1];
        assert_eq!(sparsity(&it), 0.5);
        let theta = vec![0.5, 0.0, 0.0, -0.5];
        assert!(quant_error(&theta, &it, 0.5) < 1e-6);
    }

    #[test]
    fn rebuild_preserves_biases() {
        use crate::model::tests::toy_schema;
        use crate::model::init_params;
        use crate::util::rng::Pcg;
        let schema = toy_schema();
        let mut rng = Pcg::seeded(9);
        let base = init_params(&schema, &mut rng);
        let qidx = schema.quantized_indices();
        let patterns = requantize_paramset(&base, &qidx, 0.05);
        let rebuilt = rebuild_from_ternary(&base, &qidx, &patterns);
        // biases untouched
        assert_eq!(rebuilt.tensors[1].data, base.tensors[1].data);
        // weights ternary
        assert!(rebuilt.tensors[0]
            .data
            .iter()
            .all(|&x| x == -1.0 || x == 0.0 || x == 1.0));
    }
}
