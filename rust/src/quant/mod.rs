//! Ternary quantization math in Rust (paper §III), native mirror of
//! `python/compile/kernels/ref.py`.
//!
//! Used on the server (Algorithm 2's downstream re-quantization runs in the
//! coordinator, not through PJRT) and cross-checked against the HLO
//! `*_quantize` artifacts in the integration tests.

pub mod ternary;

pub use ternary::*;
