//! # tfed — Ternary Compression for Communication-Efficient Federated Learning
//!
//! Rust + JAX + Pallas reproduction of Xu et al., *"Ternary Compression for
//! Communication-Efficient Federated Learning"* (IEEE TNNLS 2020):
//! the FTTQ quantizer and the T-FedAvg protocol, plus the FedAvg / TTQ /
//! centralized baselines and the full paper evaluation harness.
//!
//! Architecture (see DESIGN.md):
//! * **Layer 1** — Pallas kernels (ternarize, ternary matmul), authored in
//!   `python/compile/kernels/`, AOT-lowered to HLO at build time.
//! * **Layer 2** — JAX training/eval graphs (`python/compile/`), one HLO
//!   artifact per (model × mode × batch size).
//! * **Layer 3** — this crate: the `native` layer-graph training core
//!   (composable Dense/ReLU/Conv2d/pool layers over deterministic
//!   cache-blocked row-parallel kernels, per-layer FTTQ/TTQ `QuantSlot`s,
//!   and the string-keyed `model::registry` — `mlp`, `mlp-large`, `cnn`;
//!   DESIGN.md §10), the federated coordinator (client selection,
//!   concurrent round orchestration, streaming O(model) aggregation,
//!   ternary re-quantization, availability/straggler fault models),
//!   the `compress` codec registry (ternary, STC, stochastic k-bit
//!   quantization, fp16/dense baselines) behind one `Compressor` trait,
//!   the wire codec with byte accounting, the `transport` subsystem
//!   (framed wire protocol over in-process loopback or TCP), the
//!   `scenario` engine (declarative TOML experiment manifests expanded
//!   into seed/partition/codec sweeps, with `--jobs` parallel grid
//!   execution), the `sim` subsystem (deterministic discrete-event
//!   virtual-time fleet simulator: lazily-profiled registered
//!   populations, per-client bandwidth/device models, simulated
//!   time-to-accuracy; DESIGN.md §9), the data pipeline with
//!   IID/Nc/beta/Dirichlet(α) partitioners, the `obs` observability
//!   subsystem (metrics registry + span-based phase tracing + round
//!   profiler + learning-dynamics telemetry with a live HTTP endpoint,
//!   the offline `tfed report` renderer, and the append-only cross-run
//!   ledger behind `tfed history`/`query`/`diff`, off by default and
//!   free when off; DESIGN.md §11–12, §14), the `eval` per-round result
//!   records, and the PJRT runtime that executes the artifacts. Python
//!   never runs at request time.

pub mod comms;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod native;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod transport;
pub mod util;
