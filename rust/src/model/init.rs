//! Parameter initialization matching python models.py.

use crate::model::Tensor;
use crate::util::rng::Pcg;

/// U(-1/sqrt(fan_in), +1/sqrt(fan_in)) — models.py `_uniform_fanin`.
pub fn uniform_fanin(shape: Vec<usize>, fan_in: usize, rng: &mut Pcg) -> Tensor {
    let bound = 1.0 / (fan_in as f32).sqrt();
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.uniform(-bound, bound)).collect();
    Tensor { shape, data }
}

/// N(0, sigma^2) initializer (used by synthetic data generators).
pub fn normal(shape: Vec<usize>, sigma: f32, rng: &mut Pcg) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.normal() * sigma).collect();
    Tensor { shape, data }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_bounds_and_spread() {
        let mut rng = Pcg::seeded(1);
        let t = uniform_fanin(vec![100, 100], 100, &mut rng);
        let bound = 0.1;
        assert!(t.data.iter().all(|x| x.abs() <= bound));
        let mean: f32 = t.data.iter().sum::<f32>() / t.data.len() as f32;
        assert!(mean.abs() < 0.01);
        // fills the range, not clustered at zero
        assert!(t.data.iter().any(|&x| x > 0.08));
        assert!(t.data.iter().any(|&x| x < -0.08));
    }

    #[test]
    fn normal_sigma() {
        let mut rng = Pcg::seeded(2);
        let t = normal(vec![10_000], 2.0, &mut rng);
        let var: f32 =
            t.data.iter().map(|x| x * x).sum::<f32>() / t.data.len() as f32;
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }
}
