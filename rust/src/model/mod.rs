//! Model parameter schema: named tensors, shapes, flat f32 storage.
//!
//! Mirrors `artifacts/manifest.json` (written by python aot.py): each model
//! is a positional list of named parameter tensors, some flagged
//! `quantized`. The coordinator moves `ParamSet`s around; the runtime
//! marshals them into PJRT literals by position.

pub mod init;
pub mod registry;

use anyhow::{bail, Result};

use crate::util::rng::Pcg;

/// Static description of one parameter tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub quantized: bool,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static description of a whole model (mirrors manifest["models"][name]).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSchema {
    pub name: String,
    pub input_dim: usize,
    pub num_classes: usize,
    pub optimizer: String,
    pub default_lr: f32,
    pub params: Vec<ParamSpec>,
}

impl ModelSchema {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    pub fn quantized_indices(&self) -> Vec<usize> {
        self.params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.quantized)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn num_quantized(&self) -> usize {
        self.params.iter().filter(|p| p.quantized).count()
    }

    /// Bytes of a full-precision (f32) copy of the parameters — the FedAvg
    /// per-message payload the paper's Table IV counts.
    pub fn fp32_bytes(&self) -> usize {
        self.param_count() * 4
    }
}

/// One tensor's values (f32, row-major) tied to its spec index.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }
}

/// A model's parameter values, positionally matching `ModelSchema::params`.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub tensors: Vec<Tensor>,
}

impl ParamSet {
    pub fn zeros(schema: &ModelSchema) -> Self {
        ParamSet {
            tensors: schema.params.iter().map(|p| Tensor::zeros(p.shape.clone())).collect(),
        }
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Validate against a schema (shapes + count).
    pub fn check(&self, schema: &ModelSchema) -> Result<()> {
        if self.tensors.len() != schema.params.len() {
            bail!(
                "param count mismatch: {} vs schema {}",
                self.tensors.len(),
                schema.params.len()
            );
        }
        for (t, p) in self.tensors.iter().zip(&schema.params) {
            if t.shape != p.shape {
                bail!("{}: shape {:?} vs schema {:?}", p.name, t.shape, p.shape);
            }
        }
        Ok(())
    }

    /// Weighted in-place accumulate: self += weight * other (FedAvg rule).
    pub fn axpy(&mut self, weight: f32, other: &ParamSet) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            debug_assert_eq!(a.data.len(), b.data.len());
            for (x, y) in a.data.iter_mut().zip(&b.data) {
                *x += weight * y;
            }
        }
    }

    pub fn scale(&mut self, s: f32) {
        for t in &mut self.tensors {
            for x in &mut t.data {
                *x *= s;
            }
        }
    }

    /// L2 distance to another set (weight-divergence diagnostics, Lemma 4.1).
    pub fn l2_distance(&self, other: &ParamSet) -> f64 {
        let mut acc = 0f64;
        for (a, b) in self.tensors.iter().zip(&other.tensors) {
            for (x, y) in a.data.iter().zip(&b.data) {
                let d = (*x - *y) as f64;
                acc += d * d;
            }
        }
        acc.sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.tensors.iter().all(|t| t.data.iter().all(|x| x.is_finite()))
    }
}

/// The MLP schema from the paper's Table I (784-30-20-10), identical to
/// python models.py — usable without a manifest (native backend, tests).
pub fn mlp_schema() -> ModelSchema {
    let dims = [784usize, 30, 20, 10];
    let mut params = Vec::new();
    for li in 0..dims.len() - 1 {
        params.push(ParamSpec {
            name: format!("w{}", li + 1),
            shape: vec![dims[li], dims[li + 1]],
            quantized: true,
        });
        params.push(ParamSpec {
            name: format!("b{}", li + 1),
            shape: vec![dims[li + 1]],
            quantized: false,
        });
    }
    ModelSchema {
        name: "mlp".into(),
        input_dim: 784,
        num_classes: 10,
        optimizer: "sgd".into(),
        default_lr: 0.05,
        params,
    }
}

/// Initialize parameters the same way models.py does: U(-1/sqrt(fan_in),
/// 1/sqrt(fan_in)) for quantized weights, zeros for biases.
pub fn init_params(schema: &ModelSchema, rng: &mut Pcg) -> ParamSet {
    let tensors = schema
        .params
        .iter()
        .map(|p| {
            if p.quantized {
                let fan_in: usize = p.shape[..p.shape.len() - 1].iter().product();
                init::uniform_fanin(p.shape.clone(), fan_in.max(1), rng)
            } else {
                Tensor::zeros(p.shape.clone())
            }
        })
        .collect();
    ParamSet { tensors }
}

#[cfg(test)]
pub mod tests {
    use super::*;

    pub fn toy_schema() -> ModelSchema {
        ModelSchema {
            name: "toy".into(),
            input_dim: 4,
            num_classes: 2,
            optimizer: "sgd".into(),
            default_lr: 0.1,
            params: vec![
                ParamSpec { name: "w1".into(), shape: vec![4, 3], quantized: true },
                ParamSpec { name: "b1".into(), shape: vec![3], quantized: false },
                ParamSpec { name: "w2".into(), shape: vec![3, 2], quantized: true },
                ParamSpec { name: "b2".into(), shape: vec![2], quantized: false },
            ],
        }
    }

    #[test]
    fn schema_counts() {
        let s = toy_schema();
        assert_eq!(s.param_count(), 12 + 3 + 6 + 2);
        assert_eq!(s.quantized_indices(), vec![0, 2]);
        assert_eq!(s.num_quantized(), 2);
        assert_eq!(s.fp32_bytes(), 23 * 4);
    }

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn init_respects_fanin_bound() {
        let s = toy_schema();
        let mut rng = Pcg::seeded(1);
        let p = init_params(&s, &mut rng);
        p.check(&s).unwrap();
        let bound = 1.0 / (4f32).sqrt();
        assert!(p.tensors[0].data.iter().all(|x| x.abs() <= bound));
        assert!(p.tensors[1].data.iter().all(|&x| x == 0.0));
        // not all zeros
        assert!(p.tensors[0].data.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn axpy_is_weighted_sum() {
        let s = toy_schema();
        let mut rng = Pcg::seeded(2);
        let a = init_params(&s, &mut rng);
        let b = init_params(&s, &mut rng);
        let mut acc = ParamSet::zeros(&s);
        acc.axpy(0.25, &a);
        acc.axpy(0.75, &b);
        for i in 0..s.params.len() {
            for j in 0..acc.tensors[i].data.len() {
                let want = 0.25 * a.tensors[i].data[j] + 0.75 * b.tensors[i].data[j];
                assert!((acc.tensors[i].data[j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn l2_distance_zero_for_self() {
        let s = toy_schema();
        let mut rng = Pcg::seeded(3);
        let a = init_params(&s, &mut rng);
        assert_eq!(a.l2_distance(&a), 0.0);
        let mut b = a.clone();
        b.tensors[0].data[0] += 3.0;
        assert!((b.l2_distance(&a) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn check_catches_mismatch() {
        let s = toy_schema();
        let mut p = ParamSet::zeros(&s);
        p.tensors.pop();
        assert!(p.check(&s).is_err());
    }
}
