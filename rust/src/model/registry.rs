//! String-keyed native model registry: each entry pairs a [`ModelSchema`]
//! (the positional parameter list every subsystem already speaks) with a
//! [`LayerSpec`] graph description (what the native backend needs to build
//! forward/backward layers — conv geometry, pooling, activation placement
//! — none of which fits in a `ParamSpec`).
//!
//! Registered models:
//!
//! | name        | task substrate      | architecture                              |
//! |-------------|---------------------|-------------------------------------------|
//! | `mlp`       | mnist-like (784)    | 784-30-20-10 dense, the paper's Table I   |
//! | `mlp-large` | mnist-like (784)    | 784-256-128-10 dense (perf/bench scale)   |
//! | `cnn`       | cifar-like (16x16x3)| conv3x3x8 - pool - conv3x3x16 - pool - fc |
//!
//! `mlp` is byte-identical to the seed [`mlp_schema`](crate::model::mlp_schema)
//! — same names, shapes, flags, and therefore the same `init_params` RNG
//! draw sequence — so default runs reproduce pre-registry results exactly.
//!
//! Validation is the registry's second job: [`ModelDef::validate`] checks
//! every (weight, bias) pair against the layer geometry and the layer
//! chain against the schema's input/output dims, with a typed
//! [`ModelError`]. (The seed `NativeMlp::from_schema` checked only the
//! weight ranks — a mismatched bias silently trained garbage.)

use std::fmt;

use crate::model::{ModelSchema, ParamSpec};

/// One layer of a native model's compute graph. Dense/Conv2d entries own
/// the next (weight, bias) pair of the schema's positional parameter
/// list; pool/flatten entries are parameter-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    /// Fully connected `[inp, out]` (+ bias `[out]`), optional ReLU after.
    Dense { inp: usize, out: usize, relu: bool },
    /// 2-D convolution over NHWC input `[h, w, cin]`, weights
    /// `[kh, kw, cin, cout]` (+ bias `[cout]`), stride 1, zero-padded
    /// "same" output `[h, w, cout]`, optional ReLU after. Kernel dims
    /// must be odd.
    Conv2d { h: usize, w: usize, cin: usize, cout: usize, kh: usize, kw: usize, relu: bool },
    /// 2x2 average pooling, stride 2, over `[h, w, c]` (h, w even).
    AvgPool2 { h: usize, w: usize, c: usize },
    /// Shape bookkeeping between conv and dense stages (NHWC is already
    /// flat per sample, so this is a marker, not a data transform).
    Flatten { len: usize },
}

impl LayerSpec {
    /// Per-sample (input, output) float counts.
    pub fn io(&self) -> (usize, usize) {
        match *self {
            LayerSpec::Dense { inp, out, .. } => (inp, out),
            LayerSpec::Conv2d { h, w, cin, cout, .. } => (h * w * cin, h * w * cout),
            LayerSpec::AvgPool2 { h, w, c } => (h * w * c, (h / 2) * (w / 2) * c),
            LayerSpec::Flatten { len } => (len, len),
        }
    }

    /// Expected (weight, bias) shapes, for layers that own parameters.
    pub fn param_shapes(&self) -> Option<(Vec<usize>, Vec<usize>)> {
        match *self {
            LayerSpec::Dense { inp, out, .. } => Some((vec![inp, out], vec![out])),
            LayerSpec::Conv2d { cin, cout, kh, kw, .. } => {
                Some((vec![kh, kw, cin, cout], vec![cout]))
            }
            LayerSpec::AvgPool2 { .. } | LayerSpec::Flatten { .. } => None,
        }
    }

    fn check_geometry(&self, layer: usize) -> Result<(), ModelError> {
        match *self {
            LayerSpec::Conv2d { kh, kw, .. } => {
                if kh % 2 == 0 || kw % 2 == 0 || kh == 0 || kw == 0 {
                    return Err(ModelError::Unsupported {
                        layer,
                        why: format!("conv kernels must be odd, got {kh}x{kw}"),
                    });
                }
            }
            LayerSpec::AvgPool2 { h, w, .. } => {
                if h % 2 != 0 || w % 2 != 0 || h == 0 || w == 0 {
                    return Err(ModelError::Unsupported {
                        layer,
                        why: format!("2x2 pooling needs even spatial dims, got {h}x{w}"),
                    });
                }
            }
            LayerSpec::Dense { inp, out, .. } => {
                if inp == 0 || out == 0 {
                    return Err(ModelError::Unsupported {
                        layer,
                        why: "dense dims must be positive".into(),
                    });
                }
            }
            LayerSpec::Flatten { .. } => {}
        }
        Ok(())
    }
}

/// Typed schema/graph validation error (the registry's rejection surface).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelError {
    /// Not in the native registry.
    UnknownModel { name: String },
    /// Schema parameter count disagrees with the layer graph.
    ParamCount { got: usize, want: usize },
    /// A parameter tensor's shape disagrees with its layer's geometry
    /// (e.g. a bias that doesn't match its weight's output dim).
    ShapeMismatch { param: String, got: Vec<usize>, want: Vec<usize> },
    /// Consecutive layers disagree on activation size.
    BrokenChain { layer: usize, got: usize, want: usize },
    /// First/last layer disagrees with the schema's input_dim/num_classes.
    BadBoundary { what: &'static str, got: usize, want: usize },
    /// Geometry the native kernels don't implement.
    Unsupported { layer: usize, why: String },
}

impl fmt::Display for ModelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ModelError::UnknownModel { name } => write!(
                f,
                "unknown native model {name:?} (registry: {})",
                MODEL_NAMES.join(" | ")
            ),
            ModelError::ParamCount { got, want } => {
                write!(f, "schema has {got} parameter tensors, layer graph wants {want}")
            }
            ModelError::ShapeMismatch { param, got, want } => {
                write!(f, "parameter {param:?}: shape {got:?} does not match layer geometry {want:?}")
            }
            ModelError::BrokenChain { layer, got, want } => write!(
                f,
                "layer {layer} consumes {got} values but the previous layer produces {want}"
            ),
            ModelError::BadBoundary { what, got, want } => {
                write!(f, "model {what} is {got}, schema declares {want}")
            }
            ModelError::Unsupported { layer, why } => write!(f, "layer {layer}: {why}"),
        }
    }
}

impl std::error::Error for ModelError {}

/// A native model: schema + layer graph, validated as a pair.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelDef {
    pub schema: ModelSchema,
    pub layers: Vec<LayerSpec>,
}

impl ModelDef {
    /// Check the schema against the layer graph: (w, b) shape agreement
    /// per parameterized layer, activation-size chaining, input/output
    /// boundary dims, and kernel geometry the native backend supports.
    pub fn validate(&self) -> Result<(), ModelError> {
        let mut pi = 0usize;
        let mut cur = self.schema.input_dim;
        for (li, spec) in self.layers.iter().enumerate() {
            spec.check_geometry(li)?;
            let (in_len, out_len) = spec.io();
            if cur != in_len {
                return Err(ModelError::BrokenChain { layer: li, got: in_len, want: cur });
            }
            if let Some((w_shape, b_shape)) = spec.param_shapes() {
                if pi + 1 >= self.schema.params.len() {
                    return Err(ModelError::ParamCount {
                        got: self.schema.params.len(),
                        want: pi + 2,
                    });
                }
                let w = &self.schema.params[pi];
                let b = &self.schema.params[pi + 1];
                if w.shape != w_shape {
                    return Err(ModelError::ShapeMismatch {
                        param: w.name.clone(),
                        got: w.shape.clone(),
                        want: w_shape,
                    });
                }
                if b.shape != b_shape {
                    return Err(ModelError::ShapeMismatch {
                        param: b.name.clone(),
                        got: b.shape.clone(),
                        want: b_shape,
                    });
                }
                pi += 2;
            }
            cur = out_len;
        }
        if pi != self.schema.params.len() {
            return Err(ModelError::ParamCount { got: self.schema.params.len(), want: pi });
        }
        if cur != self.schema.num_classes {
            return Err(ModelError::BadBoundary {
                what: "output",
                got: cur,
                want: self.schema.num_classes,
            });
        }
        Ok(())
    }
}

/// Names the native registry answers to, in canonical order.
pub const MODEL_NAMES: &[&str] = &["mlp", "mlp-large", "cnn"];

/// Look a model up by name. `mlp` reproduces the seed schema (and its
/// `init_params` draw sequence) byte for byte.
pub fn model_def(name: &str) -> Result<ModelDef, ModelError> {
    let def = match name {
        "mlp" => dense_stack("mlp", &[784, 30, 20, 10], 0.05),
        "mlp-large" => dense_stack("mlp-large", &[784, 256, 128, 10], 0.05),
        "cnn" => cnn_def(),
        _ => return Err(ModelError::UnknownModel { name: name.to_string() }),
    };
    debug_assert!(def.validate().is_ok(), "registry model {name} must validate");
    Ok(def)
}

/// Infer a dense (+ReLU) layer graph from any (w, b)-paired schema — the
/// seed `NativeMlp::from_schema` contract, now with full shape validation
/// (a bias that disagrees with its weight is rejected, not trained).
pub fn dense_from_schema(schema: &ModelSchema) -> Result<ModelDef, ModelError> {
    if schema.params.is_empty() || schema.params.len() % 2 != 0 {
        return Err(ModelError::ParamCount {
            got: schema.params.len(),
            want: (schema.params.len() / 2) * 2 + 2,
        });
    }
    let n_layers = schema.params.len() / 2;
    let mut layers = Vec::with_capacity(2 * n_layers - 1);
    for (i, pair) in schema.params.chunks(2).enumerate() {
        let w = &pair[0];
        if w.shape.len() != 2 {
            return Err(ModelError::Unsupported {
                layer: i,
                why: format!("dense schemas want 2-D weights, {} has shape {:?}", w.name, w.shape),
            });
        }
        layers.push(LayerSpec::Dense {
            inp: w.shape[0],
            out: w.shape[1],
            relu: i + 1 < n_layers,
        });
    }
    let def = ModelDef { schema: schema.clone(), layers };
    def.validate()?;
    Ok(def)
}

/// An MLP over `dims = [input, hidden.., classes]`: quantized weights,
/// fp biases, ReLU between layers — the seed `mlp_schema` shape.
fn dense_stack(name: &str, dims: &[usize], default_lr: f32) -> ModelDef {
    let mut params = Vec::new();
    let mut layers = Vec::new();
    for li in 0..dims.len() - 1 {
        params.push(ParamSpec {
            name: format!("w{}", li + 1),
            shape: vec![dims[li], dims[li + 1]],
            quantized: true,
        });
        params.push(ParamSpec {
            name: format!("b{}", li + 1),
            shape: vec![dims[li + 1]],
            quantized: false,
        });
        layers.push(LayerSpec::Dense {
            inp: dims[li],
            out: dims[li + 1],
            relu: li + 2 < dims.len(),
        });
    }
    ModelDef {
        schema: ModelSchema {
            name: name.into(),
            input_dim: dims[0],
            num_classes: *dims.last().unwrap(),
            optimizer: "sgd".into(),
            default_lr,
            params,
        },
        layers,
    }
}

/// The CIFAR-shaped small CNN: 16x16x3 NHWC input (the synthetic
/// cifar-like task), two quantized same-padding 3x3 conv+ReLU+avgpool
/// stages, one quantized dense head. ~4k parameters — sized for the CI
/// smoke matrix, structured like the paper's second model family.
fn cnn_def() -> ModelDef {
    let params = vec![
        ParamSpec { name: "conv1_w".into(), shape: vec![3, 3, 3, 8], quantized: true },
        ParamSpec { name: "conv1_b".into(), shape: vec![8], quantized: false },
        ParamSpec { name: "conv2_w".into(), shape: vec![3, 3, 8, 16], quantized: true },
        ParamSpec { name: "conv2_b".into(), shape: vec![16], quantized: false },
        ParamSpec { name: "fc_w".into(), shape: vec![256, 10], quantized: true },
        ParamSpec { name: "fc_b".into(), shape: vec![10], quantized: false },
    ];
    let layers = vec![
        LayerSpec::Conv2d { h: 16, w: 16, cin: 3, cout: 8, kh: 3, kw: 3, relu: true },
        LayerSpec::AvgPool2 { h: 16, w: 16, c: 8 },
        LayerSpec::Conv2d { h: 8, w: 8, cin: 8, cout: 16, kh: 3, kw: 3, relu: true },
        LayerSpec::AvgPool2 { h: 8, w: 8, c: 16 },
        LayerSpec::Flatten { len: 256 },
        LayerSpec::Dense { inp: 256, out: 10, relu: false },
    ];
    ModelDef {
        schema: ModelSchema {
            name: "cnn".into(),
            input_dim: 16 * 16 * 3,
            num_classes: 10,
            optimizer: "sgd".into(),
            default_lr: 0.01,
            params,
        },
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mlp_schema;

    #[test]
    fn registry_mlp_is_byte_identical_to_seed_schema() {
        let def = model_def("mlp").unwrap();
        assert_eq!(def.schema, mlp_schema());
    }

    #[test]
    fn every_registered_model_validates() {
        for &name in MODEL_NAMES {
            let def = model_def(name).unwrap();
            def.validate().unwrap();
            assert_eq!(def.schema.name, name);
            assert!(def.schema.num_quantized() > 0, "{name}");
        }
        assert!(matches!(
            model_def("resnetlite").unwrap_err(),
            ModelError::UnknownModel { .. }
        ));
    }

    #[test]
    fn cnn_geometry_chains() {
        let def = model_def("cnn").unwrap();
        assert_eq!(def.schema.input_dim, 768);
        assert_eq!(def.schema.param_count(), 216 + 8 + 1152 + 16 + 2560 + 10);
        let (first_in, _) = def.layers[0].io();
        assert_eq!(first_in, 768);
        let (_, last_out) = def.layers.last().unwrap().io();
        assert_eq!(last_out, 10);
    }

    #[test]
    fn mismatched_bias_is_rejected_not_silently_accepted() {
        // regression: the seed NativeMlp::from_schema accepted this schema
        let mut schema = mlp_schema();
        schema.params[1].shape = vec![7]; // b1 disagrees with w1 = [784, 30]
        let err = dense_from_schema(&schema).unwrap_err();
        assert!(
            matches!(err, ModelError::ShapeMismatch { ref param, .. } if param == "b1"),
            "{err}"
        );
    }

    #[test]
    fn broken_dense_chain_is_rejected() {
        let mut schema = mlp_schema();
        // w2 consumes 30 activations; claim it consumes 29
        schema.params[2].shape = vec![29, 20];
        let err = dense_from_schema(&schema).unwrap_err();
        assert!(matches!(err, ModelError::BrokenChain { layer: 1, .. }), "{err}");
    }

    #[test]
    fn odd_param_counts_and_bad_ranks_are_rejected() {
        let mut schema = mlp_schema();
        schema.params.pop();
        assert!(matches!(
            dense_from_schema(&schema).unwrap_err(),
            ModelError::ParamCount { .. }
        ));
        let mut schema = mlp_schema();
        schema.params[0].shape = vec![784, 30, 1];
        assert!(matches!(
            dense_from_schema(&schema).unwrap_err(),
            ModelError::Unsupported { .. }
        ));
    }

    #[test]
    fn validate_catches_bad_geometry() {
        let mut def = model_def("cnn").unwrap();
        if let LayerSpec::Conv2d { ref mut kh, .. } = def.layers[0] {
            *kh = 4; // even kernel
        }
        assert!(matches!(def.validate().unwrap_err(), ModelError::Unsupported { .. }));
        let mut def = model_def("cnn").unwrap();
        if let LayerSpec::AvgPool2 { ref mut h, .. } = def.layers[1] {
            *h = 15;
        }
        assert!(def.validate().is_err());
    }

    #[test]
    fn errors_display_readably() {
        let e = ModelError::UnknownModel { name: "vgg".into() };
        let s = format!("{e}");
        assert!(s.contains("vgg") && s.contains("mlp-large"), "{s}");
        let e = ModelError::ShapeMismatch {
            param: "b1".into(),
            got: vec![7],
            want: vec![30],
        };
        assert!(format!("{e}").contains("b1"));
    }
}
