//! Property and golden-fixture tests for the robust-aggregation registry
//! (DESIGN.md §13):
//!
//! * order invariance — shuffling the cohort must not change the
//!   aggregate (bitwise for the sort-based rules, within float-reorder
//!   tolerance for the weighted means);
//! * breakdown points — trimmed-mean and median match hand-computed
//!   references and hold the honest envelope up to their breakdown
//!   bound, then demonstrably fail beyond it (the bound is tight, not
//!   just safe);
//! * `mean` registry entry ≡ streaming [`Aggregator`] fold, bit for bit,
//!   over randomized fleets — the robust registry must not move the
//!   repo's byte-identity bar for the default path;
//! * Krum distance matrix against a hardcoded golden on a fixed
//!   8-client fixture, including the lowest-index tie-break.

use tfed::coordinator::{
    krum_distance_matrix, robust_aggregate, weighted_average, Aggregator, AggregatorSpec,
};
use tfed::model::{ParamSet, Tensor};
use tfed::util::proptest::forall;
use tfed::util::rng::Pcg;

/// Single-tensor ParamSet — aggregation is coordinate-wise, so one flat
/// tensor exercises every rule.
fn params(data: Vec<f32>) -> ParamSet {
    let shape = vec![data.len()];
    ParamSet { tensors: vec![Tensor { shape, data }] }
}

/// Cohort of `n` clients with `dim`-coordinate normal updates and random
/// sample counts in [1, 100].
fn random_fleet(rng: &mut Pcg, n: usize, dim: usize) -> Vec<(u32, u64, ParamSet)> {
    (0..n)
        .map(|i| {
            let samples = rng.below(100) as u64 + 1;
            let data = (0..dim).map(|_| rng.normal()).collect();
            (i as u32, samples, params(data))
        })
        .collect()
}

fn flat(p: &ParamSet) -> &[f32] {
    &p.tensors[0].data
}

fn assert_bitwise_eq(a: &ParamSet, b: &ParamSet, label: &str) {
    assert_eq!(a.tensors.len(), b.tensors.len(), "{label}");
    for (x, y) in a.tensors.iter().zip(&b.tensors) {
        for (u, v) in x.data.iter().zip(&y.data) {
            assert_eq!(u.to_bits(), v.to_bits(), "{label}: {u} != {v}");
        }
    }
}

// ---------------------------------------------------------------------------
// mean: registry wrapper ≡ streaming fold, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn mean_registry_entry_matches_streaming_fold_bit_for_bit() {
    forall(50, |rng| {
        let fleet = random_fleet(rng, 1 + rng.below(8) as usize, 1 + rng.below(40) as usize);

        // hand-rolled streaming reference: the exact float-op sequence
        // the server's optimistic path performs
        let total: u64 = fleet.iter().map(|(_, n, _)| *n).sum();
        let mut zero = fleet[0].2.clone();
        zero.scale(0.0);
        let mut agg = Aggregator::start(zero, total).unwrap();
        for (_, n, p) in &fleet {
            agg.fold(*n, p).unwrap();
        }
        let streamed = agg.finish().unwrap();

        let pairs: Vec<(u64, ParamSet)> =
            fleet.iter().map(|(_, n, p)| (*n, p.clone())).collect();
        let batch = weighted_average(&pairs).unwrap();
        assert_bitwise_eq(&streamed, &batch, "weighted_average vs streaming");

        let robust = robust_aggregate(AggregatorSpec::Mean, &fleet).unwrap();
        assert!(robust.clipped.is_empty());
        assert_bitwise_eq(&streamed, &robust.global, "registry mean vs streaming");
    });
}

// ---------------------------------------------------------------------------
// order invariance
// ---------------------------------------------------------------------------

#[test]
fn sort_based_rules_are_bitwise_order_invariant() {
    // trimmed-mean, median, and krum sort internally, so any cohort
    // permutation must yield the exact same bits
    let specs = [
        AggregatorSpec::TrimmedMean { beta: 0.25 },
        AggregatorSpec::Median,
        AggregatorSpec::Krum { f: 1 },
    ];
    forall(30, |rng| {
        let fleet = random_fleet(rng, 5, 9);
        let mut shuffled = fleet.clone();
        rng.shuffle(&mut shuffled);
        let mut reversed = fleet.clone();
        reversed.reverse();
        for spec in specs {
            let label = spec.name();
            let a = robust_aggregate(spec, &fleet).unwrap().global;
            let b = robust_aggregate(spec, &shuffled).unwrap().global;
            let c = robust_aggregate(spec, &reversed).unwrap().global;
            assert_bitwise_eq(&a, &b, &label);
            assert_bitwise_eq(&a, &c, &label);
        }
    });
}

#[test]
fn weighted_rules_are_order_invariant_within_float_tolerance() {
    // mean and norm_clip accumulate in cohort order; permutations may
    // reassociate float additions but must agree to reorder tolerance,
    // and norm_clip must flag the same client set either way
    forall(30, |rng| {
        let fleet = random_fleet(rng, 6, 9);
        let mut reversed = fleet.clone();
        reversed.reverse();
        for spec in [AggregatorSpec::Mean, AggregatorSpec::NormClip { tau: 1.2 }] {
            let a = robust_aggregate(spec, &fleet).unwrap();
            let b = robust_aggregate(spec, &reversed).unwrap();
            assert!(
                a.global.l2_distance(&b.global) < 1e-5,
                "{}: reorder moved the aggregate by {}",
                spec.name(),
                a.global.l2_distance(&b.global)
            );
            let mut ca = a.clipped.clone();
            let mut cb = b.clipped.clone();
            ca.sort_unstable();
            cb.sort_unstable();
            assert_eq!(ca, cb, "{}: clip set changed under reorder", spec.name());
        }
    });
}

// ---------------------------------------------------------------------------
// breakdown points, against hand-rolled references
// ---------------------------------------------------------------------------

/// Honest single-coordinate cohort: tight cluster around 1.0.
const HONEST: [f32; 4] = [0.9, 1.0, 1.05, 1.1];

fn one_dim_fleet(values: &[f32]) -> Vec<(u32, u64, ParamSet)> {
    values.iter().enumerate().map(|(i, &v)| (i as u32, 10, params(vec![v]))).collect()
}

#[test]
fn trimmed_mean_matches_hand_computed_reference() {
    // n = 5, beta = 0.2 → trim k = floor(0.2·5) = 1 from each end:
    // sorted [0.9, 1.0, 1.05, 1.1, 1000] keeps [1.0, 1.05, 1.1]
    let mut values = HONEST.to_vec();
    values.push(1000.0);
    let fleet = one_dim_fleet(&values);
    let spec = AggregatorSpec::TrimmedMean { beta: 0.2 };
    let got = robust_aggregate(spec, &fleet).unwrap().global;
    let want = ((1.0f64 + 1.05f32 as f64 + 1.1f32 as f64) / 3.0) as f32;
    assert_eq!(flat(&got), &[want]);
}

#[test]
fn trimmed_mean_holds_the_envelope_up_to_its_breakdown_point_and_not_beyond() {
    // beta = 0.2 on n = 5 trims one value per end: one poisoned client
    // is absorbed, two overwhelm the trim and drag the aggregate out
    let spec = AggregatorSpec::TrimmedMean { beta: 0.2 };
    let lo = HONEST.iter().copied().min_by(f32::total_cmp).unwrap();
    let hi = HONEST.iter().copied().max_by(f32::total_cmp).unwrap();

    let mut one_poison = HONEST.to_vec();
    one_poison.push(1000.0);
    let v = flat(&robust_aggregate(spec, &one_dim_fleet(&one_poison)).unwrap().global)[0];
    assert!((lo..=hi).contains(&v), "one poison escaped the trim: {v}");

    let two_poison = [HONEST[0], HONEST[1], HONEST[2], 1000.0, 1000.0];
    let v = flat(&robust_aggregate(spec, &one_dim_fleet(&two_poison)).unwrap().global)[0];
    assert!(v > hi, "two poisons past the breakdown point were absorbed: {v}");
}

#[test]
fn median_matches_hand_computed_reference_and_breakdown() {
    // odd cohort: middle value; even cohort: mean of the two middles
    let got = robust_aggregate(
        AggregatorSpec::Median,
        &one_dim_fleet(&[3.0, 1.0, 2.0, 5.0, 4.0]),
    )
    .unwrap()
    .global;
    assert_eq!(flat(&got), &[3.0]);
    let got = robust_aggregate(AggregatorSpec::Median, &one_dim_fleet(&[4.0, 1.0, 2.0, 3.0]))
        .unwrap()
        .global;
    assert_eq!(flat(&got), &[2.5]);

    // breakdown: a minority of poisons cannot move the median out of
    // the honest envelope; a majority owns it
    let lo = HONEST.iter().copied().min_by(f32::total_cmp).unwrap();
    let hi = HONEST.iter().copied().max_by(f32::total_cmp).unwrap();
    let minority = [HONEST[0], HONEST[1], HONEST[2], 1000.0, 1000.0];
    let v = flat(&robust_aggregate(AggregatorSpec::Median, &one_dim_fleet(&minority))
        .unwrap()
        .global)[0];
    assert!((lo..=hi).contains(&v), "minority poisons moved the median: {v}");
    let majority = [HONEST[0], HONEST[1], 1000.0, 1000.0, 1000.0];
    let v = flat(&robust_aggregate(AggregatorSpec::Median, &one_dim_fleet(&majority))
        .unwrap()
        .global)[0];
    assert_eq!(v, 1000.0, "a poisoned majority must own the median");
}

#[test]
fn norm_clip_flags_exactly_the_outlier_and_bounds_its_pull() {
    // three unit-scale updates and one at 100x: only the outlier is
    // clipped, and the aggregate stays near the honest mean instead of
    // being dragged a quarter of the way to 100
    let fleet = vec![
        (0u32, 10u64, params(vec![1.0, 0.0])),
        (1, 10, params(vec![0.0, 1.0])),
        (2, 10, params(vec![0.5, 0.5])),
        (3, 10, params(vec![100.0, 0.0])),
    ];
    let out = robust_aggregate(AggregatorSpec::NormClip { tau: 1.5 }, &fleet).unwrap();
    assert_eq!(out.clipped, vec![3]);
    let honest_mean = robust_aggregate(AggregatorSpec::Mean, &fleet[..3]).unwrap();
    assert!(
        out.global.l2_distance(&honest_mean.global) < 1.0,
        "clipped aggregate strayed {} from the honest mean",
        out.global.l2_distance(&honest_mean.global)
    );
    let undefended = robust_aggregate(AggregatorSpec::Mean, &fleet).unwrap();
    assert!(undefended.global.l2_distance(&honest_mean.global) > 10.0);
}

// ---------------------------------------------------------------------------
// Krum golden fixture
// ---------------------------------------------------------------------------

/// Fixed 8-client fixture: client `i` holds the tensor `[i, 2i]`, so
/// dist²(i, j) = (i−j)² + (2i−2j)² = 5(i−j)² exactly in f64.
fn krum_fixture() -> Vec<(u32, u64, ParamSet)> {
    (0..8u32)
        .map(|i| (i, 10, params(vec![i as f32, 2.0 * i as f32])))
        .collect()
}

#[test]
fn krum_distance_matrix_matches_the_golden() {
    #[rustfmt::skip]
    const GOLDEN: [f64; 64] = [
          0.0,   5.0,  20.0,  45.0,  80.0, 125.0, 180.0, 245.0,
          5.0,   0.0,   5.0,  20.0,  45.0,  80.0, 125.0, 180.0,
         20.0,   5.0,   0.0,   5.0,  20.0,  45.0,  80.0, 125.0,
         45.0,  20.0,   5.0,   0.0,   5.0,  20.0,  45.0,  80.0,
         80.0,  45.0,  20.0,   5.0,   0.0,   5.0,  20.0,  45.0,
        125.0,  80.0,  45.0,  20.0,   5.0,   0.0,   5.0,  20.0,
        180.0, 125.0,  80.0,  45.0,  20.0,   5.0,   0.0,   5.0,
        245.0, 180.0, 125.0,  80.0,  45.0,  20.0,   5.0,   0.0,
    ];
    let dist2 = krum_distance_matrix(&krum_fixture());
    assert_eq!(dist2.len(), 64);
    for (idx, (&got, &want)) in dist2.iter().zip(GOLDEN.iter()).enumerate() {
        assert!(
            (got - want).abs() < 1e-9,
            "dist2[{}][{}] = {got}, golden says {want}",
            idx / 8,
            idx % 8
        );
    }
}

#[test]
fn krum_selects_the_lowest_index_among_tied_central_members() {
    // with f = 1 on n = 8 colinear clients, indices 2..=5 tie on the
    // 5-nearest-neighbor score; the registry pins ties to the lowest
    // index, and the winner is returned verbatim
    let fleet = krum_fixture();
    let got = robust_aggregate(AggregatorSpec::Krum { f: 1 }, &fleet).unwrap().global;
    assert_bitwise_eq(&got, &fleet[2].2, "krum tie-break");
}

#[test]
fn krum_always_returns_a_cohort_member_verbatim() {
    forall(30, |rng| {
        let fleet = random_fleet(rng, 2 + rng.below(6) as usize, 5);
        let got = robust_aggregate(AggregatorSpec::Krum { f: 1 }, &fleet).unwrap().global;
        assert!(
            fleet.iter().any(|(_, _, p)| {
                flat(p).iter().zip(flat(&got)).all(|(a, b)| a.to_bits() == b.to_bits())
            }),
            "krum synthesized a tensor outside the cohort"
        );
    });
}

// ---------------------------------------------------------------------------
// registry surface
// ---------------------------------------------------------------------------

#[test]
fn shape_disagreement_is_a_typed_error_for_every_rule() {
    let fleet = vec![
        (0u32, 10u64, params(vec![1.0, 2.0])),
        (1, 10, params(vec![1.0, 2.0, 3.0])),
    ];
    for name in tfed::coordinator::aggregator_names() {
        let spec = AggregatorSpec::parse(name).unwrap();
        let err = robust_aggregate(spec, &fleet).unwrap_err();
        assert!(
            format!("{err:#}").contains("shape disagrees"),
            "{name}: unexpected error {err:#}"
        );
    }
}
