//! Learning-telemetry regression tests (DESIGN.md §12).
//!
//! Pins the PR's standing contract and schema:
//! * **byte identity** — telemetry on changes only the new sink files:
//!   run metrics and sim bundles stay byte-identical with it on and off;
//! * **JSONL schema** — one v1 record per round with the documented key
//!   set; two identical runs (and `--jobs` grids at any parallelism)
//!   serialize to byte-equal JSONL;
//! * **the per-round math** — unbiasedness residual, weight divergence,
//!   and zero fractions against hand-computed values;
//! * **the live endpoint** — `/metrics` and `/telemetry` round-trip over
//!   a real socket;
//! * **`tfed report`** — the compression-ratio table and the telemetry
//!   series render from artifacts alone, and schema drift is rejected.
//!
//! Telemetry state is process-global, so every test serializes on one
//! lock and restores the disabled default before releasing it.

mod common;

use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::sync::Mutex;

use common::fingerprint;
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::run_experiment;
use tfed::model::{ParamSet, Tensor};
use tfed::obs::{telemetry, trace};
use tfed::scenario::{run_scenario, run_scenario_jobs, ScenarioManifest};
use tfed::util::json::Json;

static OBS_LOCK: Mutex<()> = Mutex::new(());

/// Restore the default-off state (and drop any collected records/spans).
fn obs_off() {
    telemetry::set_enabled(false);
    telemetry::clear();
    trace::set_enabled(false);
    trace::clear();
}

fn small_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, seed);
    cfg.n_clients = 3;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 300;
    cfg.test_samples = 60;
    cfg.batch = 16;
    cfg.native_backend = true;
    cfg
}

const SIM_MANIFEST: &str = r#"
[scenario]
name = "telemetry_sim"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 300
test_samples = 60
seed = 7
native = true
[sim]
registered_clients = 50
"#;

/// Two-cell sweep for the `--jobs` determinism claim.
const SWEEP_MANIFEST: &str = r#"
[scenario]
name = "telemetry_sweep"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 300
test_samples = 60
seed = 7
native = true
[sweep]
seeds = [1, 2]
"#;

#[test]
fn enabling_telemetry_is_byte_invisible() {
    let _g = OBS_LOCK.lock().unwrap();
    obs_off();
    let cfg = small_cfg(42);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let baseline = run_experiment(cfg.clone(), backend.as_ref()).unwrap();
    let sim_baseline =
        run_scenario(&ScenarioManifest::parse(SIM_MANIFEST).unwrap()).unwrap();

    tfed::obs::enable_telemetry();
    let on = run_experiment(cfg, backend.as_ref()).unwrap();
    let sim_on = run_scenario(&ScenarioManifest::parse(SIM_MANIFEST).unwrap()).unwrap();
    let n_records = telemetry::take().len();
    obs_off();

    // same losses, accuracies, selections, and wire bytes, byte for byte
    assert_eq!(fingerprint(&baseline), fingerprint(&on));
    assert_eq!(
        sim_baseline.to_json().to_string_pretty(),
        sim_on.to_json().to_string_pretty()
    );
    // and the enabled pass did collect per-round records (2 rounds each)
    assert_eq!(n_records, 4);
}

#[test]
fn jsonl_records_have_the_v1_schema_and_deterministic_bytes() {
    let _g = OBS_LOCK.lock().unwrap();
    obs_off();
    tfed::obs::enable_telemetry();
    let cfg = small_cfg(7);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    run_experiment(cfg.clone(), backend.as_ref()).unwrap();
    let jsonl = telemetry::to_jsonl(&telemetry::take());
    // one record per round, in round order
    assert_eq!(jsonl.lines().count(), cfg.rounds);
    const KEYS: &[&str] = &[
        "v",
        "lane",
        "round",
        "cell",
        "protocol",
        "train_loss",
        "test_acc",
        "test_loss",
        "evaluated",
        "factors",
        "layer_zero_fraction",
        "sparsity",
        "unbias_residual",
        "weight_divergence",
        "rel_divergence",
        "cum_up_bytes",
        "cum_down_bytes",
        "sim_secs",
        "rejected",
        "clipped",
    ];
    let mut last_up = 0u64;
    for (i, line) in jsonl.lines().enumerate() {
        let doc = Json::parse(line).unwrap();
        for k in KEYS {
            assert!(doc.get(k).is_some(), "missing {k} in {line}");
        }
        // exactly the documented keys, no stragglers
        if let Json::Obj(m) = &doc {
            assert_eq!(m.len(), KEYS.len(), "unexpected keys in {line}");
        } else {
            panic!("record is not an object: {line}");
        }
        assert_eq!(
            doc.get("v").unwrap().as_usize().unwrap() as u64,
            telemetry::SCHEMA_VERSION
        );
        assert_eq!(doc.get("round").unwrap().as_usize().unwrap(), i + 1);
        assert_eq!(doc.get("protocol").unwrap().as_str().unwrap(), "T-FedAvg");
        // T-FedAvg on the mlp: one factor + one zero fraction per
        // quantized layer, real sparsity, cumulative bytes monotone
        let factors = doc.get("factors").unwrap().as_arr().unwrap();
        let zf = doc.get("layer_zero_fraction").unwrap().as_arr().unwrap();
        assert!(!factors.is_empty());
        assert_eq!(factors.len(), zf.len());
        let sparsity = doc.get("sparsity").unwrap().as_f64().unwrap();
        assert!(sparsity > 0.0 && sparsity < 1.0, "sparsity {sparsity}");
        assert!(doc.get("weight_divergence").unwrap().as_f64().unwrap() >= 0.0);
        let up = doc.get("cum_up_bytes").unwrap().as_f64().unwrap() as u64;
        assert!(up > last_up, "cumulative up bytes must grow: {last_up} -> {up}");
        last_up = up;
    }

    // golden determinism: an identical rerun produces byte-equal JSONL
    // (records carry no wall-clock fields by design)
    run_experiment(cfg, backend.as_ref()).unwrap();
    let jsonl2 = telemetry::to_jsonl(&telemetry::take());
    obs_off();
    assert_eq!(jsonl, jsonl2);
}

#[test]
fn jobs_grids_drain_to_identical_jsonl() {
    let _g = OBS_LOCK.lock().unwrap();
    obs_off();
    tfed::obs::enable_telemetry();
    let manifest = ScenarioManifest::parse(SWEEP_MANIFEST).unwrap();
    run_scenario_jobs(&manifest, 1).unwrap();
    let sequential = telemetry::to_jsonl(&telemetry::take());
    run_scenario_jobs(&manifest, 2).unwrap();
    let parallel = telemetry::to_jsonl(&telemetry::take());
    obs_off();
    // the drain sorts by (lane, round): any parallelism, same bytes
    assert_eq!(sequential, parallel);
    // both lanes present, stamped with their grid-cell labels
    let lanes: Vec<u64> = sequential
        .lines()
        .map(|l| Json::parse(l).unwrap().get("lane").unwrap().as_usize().unwrap() as u64)
        .collect();
    assert_eq!(lanes, vec![0, 0, 1, 1]);
    assert!(sequential
        .lines()
        .all(|l| !Json::parse(l).unwrap().get("cell").unwrap().as_str().unwrap().is_empty()));
}

// -- the per-round math, hand-computed --------------------------------------

fn pset(tensors: Vec<Vec<f32>>) -> ParamSet {
    ParamSet {
        tensors: tensors
            .into_iter()
            .map(|data| Tensor { shape: vec![data.len()], data })
            .collect(),
    }
}

#[test]
fn unbias_residual_matches_hand_computation() {
    let reference = pset(vec![vec![1.0, 2.0, -1.0, 0.0], vec![10.0, 10.0]]);
    let proj = pset(vec![vec![0.5, 2.5, -1.5, 0.0], vec![0.0, 0.0]]);
    // only tensor 0 is quantized: diffs are (-0.5, +0.5, -0.5, 0)/4
    let r = telemetry::unbias_residual(&reference, &proj, &[0]);
    assert!((r - (-0.125)).abs() < 1e-12, "residual {r}");
    // no quantized tensors -> 0 by definition
    assert_eq!(telemetry::unbias_residual(&reference, &proj, &[]), 0.0);
}

#[test]
fn weight_divergence_matches_hand_computation() {
    let reference = pset(vec![vec![1.0, 2.0, -1.0, 0.0]]);
    let proj = pset(vec![vec![0.5, 2.5, -1.5, 0.0]]);
    let (dist, rel) = telemetry::weight_divergence(&reference, &proj, &[0]);
    // dist^2 = 3 * 0.25; ref norm^2 = 1 + 4 + 1 = 6
    assert!((dist - 0.75f64.sqrt()).abs() < 1e-12, "dist {dist}");
    assert!((rel - (0.75f64 / 6.0).sqrt()).abs() < 1e-12, "rel {rel}");
    // zero reference norm: relative divergence defined as 0
    let zero = pset(vec![vec![0.0, 0.0]]);
    let off = pset(vec![vec![1.0, 0.0]]);
    let (dist, rel) = telemetry::weight_divergence(&zero, &off, &[0]);
    assert_eq!((dist, rel), (1.0, 0.0));
}

#[test]
fn zero_fractions_match_hand_computation() {
    let proj = pset(vec![vec![0.0, 1.0, 0.0, -1.0], vec![2.0, 3.0]]);
    let (per_layer, overall) = telemetry::zero_fractions(&proj, &[0, 1]);
    assert_eq!(per_layer, vec![0.5, 0.0]);
    assert!((overall - 2.0 / 6.0).abs() < 1e-12);
    let (per_layer, overall) = telemetry::zero_fractions(&proj, &[]);
    assert_eq!(per_layer, Vec::<f64>::new());
    assert_eq!(overall, 0.0);
}

// -- the live endpoint ------------------------------------------------------

fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).unwrap();
    buf
}

#[test]
fn http_endpoint_round_trips() {
    let _g = OBS_LOCK.lock().unwrap();
    obs_off();
    tfed::obs::enable_telemetry();
    // put real state behind the endpoint
    let cfg = small_cfg(3);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    run_experiment(cfg, backend.as_ref()).unwrap();

    let server = tfed::obs::http::serve("127.0.0.1:0").unwrap();
    let addr = server.addr();
    let metrics = http_get(addr, "/metrics");
    assert!(metrics.starts_with("HTTP/1.1 200 OK"), "{metrics}");
    assert!(metrics.contains("tfed_rounds_total"), "{metrics}");
    let telem = http_get(addr, "/telemetry");
    assert!(telem.starts_with("HTTP/1.1 200 OK"));
    let body = telem.split("\r\n\r\n").nth(1).unwrap();
    let doc = Json::parse(body).unwrap();
    assert_eq!(
        doc.get("v").unwrap().as_usize().unwrap() as u64,
        telemetry::SCHEMA_VERSION
    );
    assert!(!doc.get("records").unwrap().as_arr().unwrap().is_empty());
    assert!(http_get(addr, "/nope").starts_with("HTTP/1.1 404"));
    server.shutdown();
    obs_off();
}

// -- the offline reporter ---------------------------------------------------

#[test]
fn report_renders_from_artifacts_alone() {
    let _g = OBS_LOCK.lock().unwrap();
    obs_off();
    tfed::obs::enable_telemetry();
    let results = run_scenario(&ScenarioManifest::parse(SIM_MANIFEST).unwrap()).unwrap();
    let jsonl = telemetry::to_jsonl(&telemetry::take());
    obs_off();

    // bundle -> Table-IV-style communication table + accuracy series
    let bundle = results.to_json().to_string_pretty();
    let report = tfed::obs::report::render_text("bundle.json", &bundle).unwrap();
    assert!(report.contains("Communication cost and compression ratio"));
    // the mlp row prices a dense equivalent and a real ratio
    assert!(report.contains("| mlp |"), "{report}");
    assert!(report.contains("x |"), "no computed ratio in {report}");
    assert!(report.contains("Accuracy vs MB transferred"));
    assert!(report.contains("cell,round,cum_up_mb,cum_down_mb,test_acc"));

    // telemetry sink -> factor convergence + sparsity/divergence series
    let trep = tfed::obs::report::render_text("telemetry.jsonl", &jsonl).unwrap();
    assert!(trep.contains("Quantization-factor convergence"));
    assert!(trep.contains("cell,lane,round,layer,factor"));
    assert!(trep.contains("Sparsity and weight divergence"));

    // schema drift is rejected with the version in the message
    let bad = jsonl.replace("\"v\":1", "\"v\":2");
    let err = tfed::obs::report::render_text("bad.jsonl", &bad).unwrap_err();
    assert!(format!("{err:#}").contains("schema v2"), "{err:#}");

    // empty artifacts are rejected, not rendered as empty reports
    assert!(tfed::obs::report::render_text("empty", "").is_err());
}
