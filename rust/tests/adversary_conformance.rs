//! Byzantine conformance matrix (DESIGN.md §13): every adversarial
//! behavior, against every robust-aggregation rule, over every transport
//! (loopback, TCP, sim), must end in one of exactly two outcomes —
//! the round **converges** (statistical attacks absorbed or not by the
//! configured rule) or the faulty updates are **rejected as typed
//! per-client verdicts** — never a panic, never a silent wrong answer.
//!
//! Also pinned here:
//! * honest/default runs are bit-identical whether the adversary axis is
//!   spelled out or left at its defaults (the PR-7 byte-identity bar);
//! * the server never trusts a client-reported sample count (the
//!   `wrong_samples` regression);
//! * trimmed-mean and coordinate-median recover at least the undefended
//!   `mean` accuracy under sign-flip adversaries on a Dirichlet
//!   non-IID partition (the paper-facing robustness claim);
//! * fault rejections land in the observed-availability ledger.

mod common;

use common::{fingerprint, run_over_tcp};
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::availability::AvailabilityModel;
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::Orchestrator;
use tfed::coordinator::{AdversaryModel, AdversarySpec, AggregatorSpec, Behavior};
use tfed::eval::RunMetrics;
use tfed::sim::SimSpec;

/// Every non-honest behavior that can ride the full matrix. `oversize`
/// is excluded: its frame-encode failure kills a real TCP connection at
/// the client (by design), so it gets a loopback-only test below.
const MATRIX_BEHAVIORS: &[&str] = &[
    "scale:50",
    "sign_flip",
    "replay",
    "corrupt_frame",
    "wrong_codec",
    "wrong_samples",
];

const MATRIX_AGGREGATORS: &[&str] =
    &["mean", "trimmed_mean:0.25", "median", "norm_clip:1.5", "krum:1"];

fn small_cfg(protocol: Protocol) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::table2(protocol, Task::MnistLike, 42);
    cfg.n_clients = 4;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 160;
    cfg.test_samples = 40;
    cfg.batch = 16;
    cfg.lr = 0.1;
    cfg.native_backend = true;
    cfg
}

/// First casting seed under which exactly `want` of the `n` registered
/// clients act out `behavior` — a deterministic mixed cohort, so a
/// protocol deviation never rejects the whole round and the honest rest
/// keeps the run converging.
fn seed_for_cast(behavior: &str, fraction: f64, n: u32, want: usize) -> u64 {
    (0..10_000u64)
        .find(|&seed| {
            let spec = AdversarySpec::parse(behavior, fraction, seed).unwrap();
            AdversaryModel::new(spec).unwrap().adversaries(n).len() == want
        })
        .expect("some seed yields the wanted cast size")
}

fn adversarial_cfg(behavior: &str, aggregator: &str) -> (ExperimentConfig, Vec<u32>) {
    let mut cfg = small_cfg(Protocol::TFedAvg);
    let seed = seed_for_cast(behavior, 0.5, cfg.n_clients as u32, 2);
    cfg.adversary = AdversarySpec::parse(behavior, 0.5, seed).unwrap();
    cfg.aggregator = AggregatorSpec::parse(aggregator).unwrap();
    cfg.validate().unwrap();
    let cast = AdversaryModel::new(cfg.adversary).unwrap().adversaries(cfg.n_clients as u32);
    (cfg, cast)
}

/// The matrix cell contract: finite metrics, and — with participation
/// 1.0, so every client is selected every round — protocol deviations
/// reject exactly the adversarial cast while statistical attacks reject
/// nobody.
fn assert_cell(label: &str, m: &RunMetrics, behavior: Behavior, cast: &[u32]) {
    assert!(m.final_acc().is_finite(), "{label}: non-finite accuracy");
    for rec in &m.records {
        assert!(rec.train_loss.is_finite(), "{label}: non-finite loss");
        if behavior.is_protocol_deviation() {
            assert_eq!(
                rec.rejected, cast,
                "{label} round {}: deviations must reject exactly the cast",
                rec.round
            );
        } else {
            assert!(
                rec.rejected.is_empty(),
                "{label} round {}: statistical attacks are protocol-legal",
                rec.round
            );
        }
    }
}

#[test]
fn matrix_loopback_every_behavior_against_every_aggregator() {
    for behavior in MATRIX_BEHAVIORS {
        for aggregator in MATRIX_AGGREGATORS {
            let label = format!("loopback/{behavior}/{aggregator}");
            let (cfg, cast) = adversarial_cfg(behavior, aggregator);
            assert_eq!(cast.len(), 2, "{label}");
            let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
            let mut orch = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
            orch.run().unwrap_or_else(|e| panic!("{label}: round driver died: {e:#}"));
            assert_cell(&label, &orch.metrics, cfg.adversary.behavior, &cast);
            // fault rejections are availability from aggregation's view
            let observed = orch.observed_dropout();
            if cfg.adversary.behavior.is_protocol_deviation() {
                assert_eq!(observed.rejected(), (cast.len() * cfg.rounds) as u64, "{label}");
                assert!(observed.observed_rate() > 0.0, "{label}");
            } else {
                assert_eq!(observed.rejected(), 0, "{label}");
            }
        }
    }
}

#[test]
fn matrix_tcp_every_behavior() {
    // one statistical-robust rule over real sockets; loopback already
    // covers the full aggregator axis and TCP shares the server code
    for behavior in MATRIX_BEHAVIORS {
        let label = format!("tcp/{behavior}/median");
        let (cfg, cast) = adversarial_cfg(behavior, "median");
        let (metrics, global) = run_over_tcp(&cfg);
        assert_cell(&label, &metrics, cfg.adversary.behavior, &cast);
        assert!(global.is_finite(), "{label}: non-finite global");
    }
}

#[test]
fn matrix_sim_every_behavior() {
    // the virtual fleet casts by *registered* id: the cohort is sampled
    // from 10k ids, so adversarial membership varies per round and a
    // cohort may even be all-Byzantine — in which case the round must
    // fail typed ("every update was rejected"), not panic
    for behavior in MATRIX_BEHAVIORS {
        let label = format!("sim/{behavior}/trimmed_mean");
        let mut cfg = small_cfg(Protocol::TFedAvg);
        cfg.adversary = AdversarySpec::parse(behavior, 0.25, 11).unwrap();
        cfg.aggregator = AggregatorSpec::parse("trimmed_mean:0.25").unwrap();
        cfg.validate().unwrap();
        let model = AdversaryModel::new(cfg.adversary).unwrap();
        let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
        let mut orch = Orchestrator::with_sim(
            cfg.clone(),
            backend.as_ref(),
            AvailabilityModel::always_on(),
            SimSpec::new(10_000, 8, 5),
        )
        .unwrap();
        match orch.run() {
            Ok(()) => {
                for rec in &orch.metrics.records {
                    assert!(rec.train_loss.is_finite(), "{label}");
                    // rejected ids are always a subset of the round's
                    // adversarial selections, never an honest client
                    let adv_selected: Vec<u32> = rec
                        .selected
                        .iter()
                        .map(|&rid| rid as u32)
                        .filter(|&rid| model.behavior_of(rid) != Behavior::Honest)
                        .collect();
                    for rid in &rec.rejected {
                        assert!(adv_selected.contains(rid), "{label}: rejected honest {rid}");
                    }
                    if cfg.adversary.behavior.is_protocol_deviation() {
                        assert_eq!(rec.rejected, adv_selected, "{label} round {}", rec.round);
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("rejected"), "{label}: untyped failure: {msg}");
            }
        }
    }
}

#[test]
fn oversize_is_rejected_on_loopback() {
    // the frame layer refuses to encode the payload; the exchange error
    // becomes a typed per-client rejection and the round still completes
    let (cfg, cast) = adversarial_cfg("oversize", "median");
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut orch = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
    orch.run().unwrap();
    for rec in &orch.metrics.records {
        assert_eq!(rec.rejected, cast, "round {}", rec.round);
        assert!(rec.train_loss.is_finite());
    }
}

#[test]
fn server_rejects_misreported_sample_counts() {
    // regression: the seed trusted the client-reported num_samples in
    // the aggregation weight; the server now verifies it against its own
    // shard bookkeeping and rejects the mismatch as a typed fault
    let (cfg, cast) = adversarial_cfg("wrong_samples", "mean");
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut orch = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
    orch.run().unwrap();
    for rec in &orch.metrics.records {
        assert_eq!(rec.rejected, cast, "round {}", rec.round);
    }
    // the honest majority still learned something finite
    assert!(orch.metrics.final_acc().is_finite());
    assert_eq!(orch.observed_dropout().rejected(), (cast.len() * cfg.rounds) as u64);
}

#[test]
fn honest_runs_are_bit_identical_with_the_axis_spelled_out() {
    // the PR-7 byte-identity bar: the Byzantine axis at its defaults —
    // implicit, explicit, or active-behavior-with-zero-fraction — must
    // not move a single RNG draw or output byte
    let base = small_cfg(Protocol::TFedAvg);
    let backend = make_backend(None, "mlp", base.batch, true).unwrap();
    let run = |cfg: &ExperimentConfig| {
        let mut orch = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
        orch.run().unwrap();
        (fingerprint(&orch.metrics), orch.global().clone())
    };
    let (fp_default, g_default) = run(&base);

    let mut explicit = base.clone();
    explicit.aggregator = AggregatorSpec::parse("mean").unwrap();
    explicit.adversary = AdversarySpec::parse("honest", 0.0, 0).unwrap();
    let (fp_explicit, g_explicit) = run(&explicit);
    assert_eq!(fp_default, fp_explicit);
    assert_eq!(g_default.l2_distance(&g_explicit), 0.0);

    let mut inactive = base.clone();
    inactive.adversary = AdversarySpec::parse("sign_flip", 0.0, 99).unwrap();
    assert!(!inactive.adversary.is_active());
    let (fp_inactive, g_inactive) = run(&inactive);
    assert_eq!(fp_default, fp_inactive);
    assert_eq!(g_default.l2_distance(&g_inactive), 0.0);

    // and the records never grow robustness fields on honest runs
    let json = fp_default;
    assert!(!json.contains("\"rejected\""), "honest JSON grew a rejected field");
    assert!(!json.contains("\"clipped\""), "honest JSON grew a clipped field");
}

#[test]
fn robust_rules_recover_mean_accuracy_under_sign_flip_on_dirichlet() {
    // the paper-facing claim: on a Dirichlet non-IID partition with a
    // third of the fleet sign-flipping, the undefended mean is dragged
    // toward zero (the flipped updates cancel honest mass) while
    // trimmed-mean and coordinate-median keep learning
    let mut base = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 42);
    base.n_clients = 6;
    base.rounds = 4;
    base.local_epochs = 1;
    base.train_samples = 600;
    base.test_samples = 150;
    base.batch = 16;
    base.lr = 0.1;
    base.dirichlet_alpha = 0.5;
    base.native_backend = true;
    let seed = seed_for_cast("sign_flip", 0.5, base.n_clients as u32, 2);
    base.adversary = AdversarySpec::parse("sign_flip", 0.5, seed).unwrap();

    let backend = make_backend(None, "mlp", base.batch, true).unwrap();
    let acc_of = |aggregator: &str| {
        let mut cfg = base.clone();
        cfg.aggregator = AggregatorSpec::parse(aggregator).unwrap();
        cfg.validate().unwrap();
        let mut orch = Orchestrator::new(cfg, backend.as_ref()).unwrap();
        orch.run().unwrap();
        orch.metrics.final_acc()
    };
    let mean = acc_of("mean");
    let trimmed = acc_of("trimmed_mean:0.34");
    let median = acc_of("median");
    assert!(
        trimmed >= mean - 1e-4,
        "trimmed_mean {trimmed} fell below undefended mean {mean}"
    );
    assert!(median >= mean - 1e-4, "median {median} fell below undefended mean {mean}");
}

#[test]
fn norm_clip_reports_clipped_clients_in_the_round_records() {
    // a scaled update is protocol-legal; norm_clip bounds it and the
    // round record says which client got clipped
    let (cfg, cast) = adversarial_cfg("scale:50", "norm_clip:1.5");
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut orch = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
    orch.run().unwrap();
    let clipped_total: usize = orch.metrics.records.iter().map(|r| r.clipped.len()).sum();
    assert!(clipped_total > 0, "a 50x-scaled update escaped the clip");
    for rec in &orch.metrics.records {
        for cid in &rec.clipped {
            assert!(cast.contains(cid), "clipped honest client {cid}");
        }
        assert!(rec.rejected.is_empty(), "scaling is legal, never rejected");
    }
}
