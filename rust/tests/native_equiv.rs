//! Layer-graph refactor equivalence suite.
//!
//! The `reference` module at the bottom is the seed `NativeMlp` trainer,
//! kept **verbatim** (naive scalar loops, fused ReLU, wq-array
//! threading): it is the bit-identity oracle for the refactored
//! [`LayerGraph`] on the `mlp` schema. The suite asserts:
//!
//! * (a) graph == seed trainer, bit for bit, across fp/fttq training,
//!   evaluation, and forward — at every kernel policy (naive, blocked,
//!   1..N threads);
//! * (b) finite-difference gradient checks per layer kind (dense via the
//!   mlp schema, conv/pool/flatten via a tiny CNN);
//! * (c) 1-vs-N-thread kernel bit-identity at the graph level (the
//!   kernel-level property lives in `native::kernels` unit tests);
//! * the registry's typed schema validation (the (w, b)-mismatch
//!   regression), native TTQ (new capability), and a `cnn` federation
//!   running end-to-end over loopback, TCP, and the virtual-time sim.

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::availability::AvailabilityModel;
use tfed::coordinator::backend::{make_backend, NativeBackend};
use tfed::coordinator::server::{materialize_data, run_experiment, Orchestrator};
use tfed::coordinator::ClientRuntime;
use tfed::model::registry::{LayerSpec, ModelDef, ModelError};
use tfed::model::{init_params, mlp_schema, ModelSchema, ParamSet, ParamSpec};
use tfed::native::{KernelPolicy, LayerGraph, Mode};
use tfed::sim::SimSpec;
use tfed::transport::{TcpBinding, TcpClient};
use tfed::util::rng::Pcg;

fn param_bits(p: &ParamSet) -> Vec<u32> {
    p.tensors.iter().flat_map(|t| t.data.iter().map(|v| v.to_bits())).collect()
}

fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn mlp_batches(rng: &mut Pcg, batches: usize, n: usize) -> Vec<(Vec<f32>, Vec<u32>)> {
    (0..batches)
        .map(|_| {
            // ReLU-ish sparse inputs exercise the kernels' zero-skip path
            let x: Vec<f32> = (0..n * 784).map(|_| rng.normal().max(-0.2) - 0.1).collect();
            let y: Vec<u32> = (0..n).map(|_| rng.below(10)).collect();
            (x, y)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// (a) + (c): bit-identity vs the seed trainer, at every kernel policy
// ---------------------------------------------------------------------------

#[test]
fn layer_graph_matches_seed_trainer_bit_for_bit() {
    let schema = mlp_schema();
    let policies = [
        KernelPolicy::reference(),
        KernelPolicy::threaded(1),
        KernelPolicy::threaded(2),
        KernelPolicy::threaded(4),
    ];
    for (mode, ref_mode, nq) in [
        (Mode::Fp, reference::Mode::Fp, 0usize),
        (Mode::Fttq, reference::Mode::Fttq, 3usize),
    ] {
        // seed trainer run
        let mut data_rng = Pcg::seeded(11);
        let batches = mlp_batches(&mut data_rng, 6, 32);
        let mut ref_params = init_params(&schema, &mut Pcg::seeded(5));
        let mut ref_wq = vec![0.05f32; nq];
        let net = reference::NativeMlp::from_schema(&schema, ref_mode, 0.05).unwrap();
        let mut ref_losses = Vec::new();
        for (x, y) in &batches {
            ref_losses.push(net.train_batch(&mut ref_params, &mut ref_wq, x, y, 32, 0.1).unwrap());
        }
        let (ref_eval_loss, ref_eval_acc) =
            net.evaluate(&ref_params, &ref_wq, &batches[0].0, &batches[0].1, 32);
        let ref_fwd = net.forward(&ref_params, &ref_wq, &batches[1].0, 32);

        for policy in policies {
            let graph = LayerGraph::from_schema(&schema, mode, 0.05, policy).unwrap();
            let mut params = init_params(&schema, &mut Pcg::seeded(5));
            let mut factors = vec![0.05f32; nq];
            for ((x, y), want_loss) in batches.iter().zip(&ref_losses) {
                let loss = graph.train_batch(&mut params, &mut factors, x, y, 32, 0.1).unwrap();
                assert_eq!(
                    loss.to_bits(),
                    want_loss.to_bits(),
                    "{mode:?} {policy:?}: loss diverged"
                );
            }
            assert_eq!(
                param_bits(&ref_params),
                param_bits(&params),
                "{mode:?} {policy:?}: trained parameters diverged"
            );
            assert_eq!(f32_bits(&ref_wq), f32_bits(&factors), "{mode:?} {policy:?}: wq diverged");
            let (el, ea) = graph.evaluate(&params, &factors, &batches[0].0, &batches[0].1, 32);
            assert_eq!(el.to_bits(), ref_eval_loss.to_bits());
            assert_eq!(ea.to_bits(), ref_eval_acc.to_bits());
            let fwd = graph.forward(&params, &factors, &batches[1].0, 32);
            assert_eq!(f32_bits(&ref_fwd), f32_bits(&fwd), "{mode:?} {policy:?}: forward");
        }
    }
}

#[test]
fn mlp_large_is_thread_count_invariant() {
    // no seed reference exists for mlp-large; the contract is that every
    // kernel policy computes the same bits
    let def = tfed::model::registry::model_def("mlp-large").unwrap();
    let mut data_rng = Pcg::seeded(21);
    let x: Vec<f32> = (0..64 * 784).map(|_| data_rng.normal().max(0.0)).collect();
    let y: Vec<u32> = (0..64).map(|_| data_rng.below(10)).collect();
    let mut want: Option<(Vec<u32>, Vec<u32>)> = None;
    for policy in [
        KernelPolicy::reference(),
        KernelPolicy::threaded(1),
        KernelPolicy::threaded(4),
        KernelPolicy::threaded(8),
    ] {
        let graph = LayerGraph::from_def(&def, Mode::Fttq, 0.05, policy).unwrap();
        let mut params = init_params(&def.schema, &mut Pcg::seeded(9));
        let mut factors = vec![0.05f32; graph.factors_len()];
        for _ in 0..2 {
            graph.train_batch(&mut params, &mut factors, &x, &y, 64, 0.05).unwrap();
        }
        let got = (param_bits(&params), f32_bits(&factors));
        match &want {
            None => want = Some(got),
            Some(w) => assert_eq!(w, &got, "{policy:?} diverged"),
        }
    }
}

// ---------------------------------------------------------------------------
// (b) finite-difference gradient checks per layer kind
// ---------------------------------------------------------------------------

fn tiny_cnn_def() -> ModelDef {
    let schema = ModelSchema {
        name: "tiny-cnn".into(),
        input_dim: 6 * 6 * 2,
        num_classes: 4,
        optimizer: "sgd".into(),
        default_lr: 0.05,
        params: vec![
            ParamSpec { name: "cw".into(), shape: vec![3, 3, 2, 3], quantized: true },
            ParamSpec { name: "cb".into(), shape: vec![3], quantized: false },
            ParamSpec { name: "fw".into(), shape: vec![27, 4], quantized: true },
            ParamSpec { name: "fb".into(), shape: vec![4], quantized: false },
        ],
    };
    let layers = vec![
        LayerSpec::Conv2d { h: 6, w: 6, cin: 2, cout: 3, kh: 3, kw: 3, relu: true },
        LayerSpec::AvgPool2 { h: 6, w: 6, c: 3 },
        LayerSpec::Flatten { len: 27 },
        LayerSpec::Dense { inp: 27, out: 4, relu: false },
    ];
    let def = ModelDef { schema, layers };
    def.validate().unwrap();
    def
}

#[test]
fn gradcheck_conv_pool_flatten_dense() {
    let def = tiny_cnn_def();
    let mut rng = Pcg::seeded(31);
    let params0 = init_params(&def.schema, &mut rng);
    let n = 6usize;
    let x: Vec<f32> = (0..n * 72).map(|_| rng.normal()).collect();
    let y: Vec<u32> = (0..n).map(|_| rng.below(4)).collect();
    let graph = LayerGraph::from_def(&def, Mode::Fp, 0.05, KernelPolicy::default()).unwrap();

    // analytic step with tiny lr approximates -lr * grad
    let lr = 1e-3f32;
    let mut p_stepped = params0.clone();
    graph.train_batch(&mut p_stepped, &mut [], &x, &y, n, lr).unwrap();

    let loss_at = |p: &ParamSet| graph.evaluate(p, &[], &x, &y, n).0;
    // coordinates across every tensor kind: conv w, conv b, fc w, fc b
    for (ti, ci) in [
        (0usize, 0usize),
        (0, 25),
        (0, 53),
        (1, 1),
        (2, 0),
        (2, 60),
        (3, 2),
    ] {
        let eps = 1e-3f32;
        let mut pp = params0.clone();
        pp.tensors[ti].data[ci] += eps;
        let mut pm = params0.clone();
        pm.tensors[ti].data[ci] -= eps;
        let g_num = (loss_at(&pp) - loss_at(&pm)) / (2.0 * eps);
        let g_ana = (params0.tensors[ti].data[ci] - p_stepped.tensors[ti].data[ci]) / lr;
        assert!(
            (g_num - g_ana).abs() < 2e-2 + 0.15 * g_num.abs(),
            "tensor {ti}[{ci}]: num {g_num} vs ana {g_ana}"
        );
    }
}

// ---------------------------------------------------------------------------
// registry validation regression + native TTQ
// ---------------------------------------------------------------------------

#[test]
fn backend_rejects_mismatched_bias_shapes() {
    // regression: the seed NativeMlp::from_schema accepted any bias shape
    let mut schema = mlp_schema();
    schema.params[1].shape = vec![7]; // b1 disagrees with w1 = [784, 30]
    let err = NativeBackend::new(schema, 16).err().expect("must reject");
    let model_err = err.downcast_ref::<ModelError>().expect("typed ModelError");
    assert!(
        matches!(model_err, ModelError::ShapeMismatch { param, .. } if param == "b1"),
        "{model_err}"
    );
    // the good schema still builds
    NativeBackend::new(mlp_schema(), 16).unwrap();
}

#[test]
fn native_ttq_centralized_protocol_runs() {
    // TTQ was PJRT-only before the layer graph; now it runs natively
    let mut cfg = ExperimentConfig::table2(Protocol::Ttq, Task::MnistLike, 3);
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 300;
    cfg.test_samples = 100;
    cfg.batch = 16;
    cfg.lr = 0.1;
    cfg.native_backend = true;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let m = run_experiment(cfg, backend.as_ref()).unwrap();
    assert_eq!(m.records.len(), 2);
    // wp || wn factors per quantized layer carried across rounds
    assert_eq!(m.records[1].factors.len(), 6);
    assert!(m.records[1].factors.iter().all(|f| f.is_finite()));
    assert!(m.final_acc().is_finite());
    assert!(m.records.iter().all(|r| r.train_loss.is_finite()));
}

// ---------------------------------------------------------------------------
// cnn end-to-end: loopback == tcp, and the virtual-time sim runs it
// ---------------------------------------------------------------------------

fn cnn_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::CifarLike, 42);
    cfg.model = "cnn".into();
    cfg.n_clients = 3;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 240;
    cfg.test_samples = 60;
    cfg.batch = 16;
    cfg.lr = 0.05;
    cfg.native_backend = true;
    cfg
}

#[test]
fn cnn_federation_loopback_matches_tcp_bit_for_bit() {
    let cfg = cnn_cfg();
    let backend = make_backend(None, "cnn", cfg.batch, true).unwrap();
    // loopback reference
    let mut lb = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
    lb.run().unwrap();
    // real sockets, in-thread clients
    let binding = TcpBinding::bind("127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap();
    let (shards, _test) = materialize_data(&cfg, backend.schema().input_dim).unwrap();
    let (tcp_metrics, tcp_global) = std::thread::scope(|s| {
        for (cid, shard) in shards.into_iter().enumerate() {
            let backend = backend.as_ref();
            let want_cfg = cfg.clone();
            s.spawn(move || {
                let (mut client, got_cfg) =
                    TcpClient::connect(&addr.to_string(), cid as u32).unwrap();
                // the model override survives the wire handshake
                assert_eq!(got_cfg, want_cfg);
                assert_eq!(got_cfg.model_name(), "cnn");
                let runtime = ClientRuntime {
                    client_id: cid as u32,
                    backend,
                    shard,
                    local_epochs: got_cfg.local_epochs,
                    lr: got_cfg.lr,
                    codec: got_cfg.codec,
                    adversary: Default::default(),
                };
                client.serve(&runtime).unwrap();
            });
        }
        let transport = binding.accept_clients(cfg.n_clients, &cfg).unwrap();
        let mut orch = Orchestrator::with_transport(
            cfg.clone(),
            backend.as_ref(),
            AvailabilityModel::always_on(),
            Box::new(transport),
        )
        .unwrap();
        let run_result = orch.run();
        orch.shutdown_transport().unwrap();
        run_result.unwrap();
        (orch.metrics.clone(), orch.global().clone())
    });
    assert_eq!(lb.global().l2_distance(&tcp_global), 0.0);
    for (l, t) in lb.metrics.records.iter().zip(&tcp_metrics.records) {
        assert_eq!(l.up_bytes, t.up_bytes);
        assert_eq!(l.down_bytes, t.down_bytes);
        assert_eq!(l.test_acc.to_bits(), t.test_acc.to_bits());
        assert_eq!(l.train_loss.to_bits(), t.train_loss.to_bits());
    }
    assert!(lb.metrics.final_acc().is_finite());
}

#[test]
fn cnn_federation_runs_on_the_virtual_time_sim() {
    let cfg = cnn_cfg();
    let backend = make_backend(None, "cnn", cfg.batch, true).unwrap();
    let sim = SimSpec::new(50, 3, 9);
    let mut orch = Orchestrator::with_sim(
        cfg,
        backend.as_ref(),
        AvailabilityModel::always_on(),
        sim,
    )
    .unwrap();
    orch.run().unwrap();
    assert_eq!(orch.metrics.records.len(), 2);
    for r in &orch.metrics.records {
        assert!(r.sim_secs > 0.0, "virtual round time must advance");
        assert!(r.up_bytes > 0 && r.down_bytes > 0);
    }
    assert!(orch.metrics.final_acc().is_finite());
}

// ---------------------------------------------------------------------------
// the seed trainer, verbatim (bit-identity oracle — do not "improve")
// ---------------------------------------------------------------------------

#[allow(dead_code)]
mod reference {
    use anyhow::{bail, Result};
    use tfed::model::{ModelSchema, ParamSet};
    use tfed::quant;

    /// Which training math to run (mirrors the artifact "mode").
    #[derive(Clone, Copy, Debug, PartialEq)]
    pub enum Mode {
        Fp,
        Fttq,
    }

    /// Dimensions of one dense layer.
    #[derive(Clone, Copy, Debug)]
    struct LayerDims {
        inp: usize,
        out: usize,
    }

    /// Pure-Rust MLP trainer over a ParamSet laid out as [w1,b1,w2,b2,w3,b3].
    pub struct NativeMlp {
        layers: Vec<LayerDims>,
        t_k: f32,
        mode: Mode,
    }

    impl NativeMlp {
        pub fn from_schema(schema: &ModelSchema, mode: Mode, t_k: f32) -> Result<Self> {
            if schema.params.len() % 2 != 0 {
                bail!("expected (w, b) pairs");
            }
            let mut layers = Vec::new();
            for pair in schema.params.chunks(2) {
                let w = &pair[0];
                if w.shape.len() != 2 {
                    bail!("native backend only supports dense layers, got {:?}", w.shape);
                }
                layers.push(LayerDims { inp: w.shape[0], out: w.shape[1] });
            }
            Ok(NativeMlp { layers, t_k, mode })
        }

        fn check(&self, params: &ParamSet) -> Result<()> {
            if params.tensors.len() != self.layers.len() * 2 {
                bail!("param count mismatch");
            }
            Ok(())
        }

        /// Forward pass -> logits [n, classes]. In Fttq mode the weights are
        /// ternarized with the paper's pipeline first (wq per layer).
        pub fn forward(&self, params: &ParamSet, wq: &[f32], x: &[f32], n: usize) -> Vec<f32> {
            let mut act = x.to_vec();
            let mut cur = self.layers[0].inp;
            for (li, dims) in self.layers.iter().enumerate() {
                let w = &params.tensors[li * 2].data;
                let b = &params.tensors[li * 2 + 1].data;
                let w_eff: Vec<f32> = match self.mode {
                    Mode::Fp => w.clone(),
                    Mode::Fttq => {
                        let (it, _) = quant::fttq_quantize(w, self.t_k);
                        quant::dequantize(&it, wq[li])
                    }
                };
                let mut next = vec![0f32; n * dims.out];
                matmul_bias(&act, &w_eff, b, &mut next, n, cur, dims.out);
                if li + 1 < self.layers.len() {
                    for v in &mut next {
                        *v = v.max(0.0);
                    }
                }
                act = next;
                cur = dims.out;
            }
            act
        }

        /// (mean masked CE loss, accuracy) without updating anything.
        pub fn evaluate(
            &self,
            params: &ParamSet,
            wq: &[f32],
            x: &[f32],
            y: &[u32],
            n: usize,
        ) -> (f32, f32) {
            let classes = self.layers.last().unwrap().out;
            let logits = self.forward(params, wq, x, n);
            let mut loss = 0f64;
            let mut correct = 0usize;
            for i in 0..n {
                let row = &logits[i * classes..(i + 1) * classes];
                let (lse, argmax) = log_sum_exp(row);
                loss += (lse - row[y[i] as usize]) as f64;
                if argmax == y[i] as usize {
                    correct += 1;
                }
            }
            ((loss / n as f64) as f32, correct as f32 / n as f32)
        }

        /// One SGD step over a batch; updates params (and wq in Fttq mode)
        /// in place. Returns the batch mean loss.
        pub fn train_batch(
            &self,
            params: &mut ParamSet,
            wq: &mut [f32],
            x: &[f32],
            y: &[u32],
            n: usize,
            lr: f32,
        ) -> Result<f32> {
            self.check(params)?;
            let l = self.layers.len();
            let classes = self.layers[l - 1].out;

            // ---- forward, keeping activations + ternary patterns ----
            let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
            let mut terns: Vec<Option<(Vec<i8>, Vec<f32>)>> = Vec::with_capacity(l);
            let mut cur = self.layers[0].inp;
            for (li, dims) in self.layers.iter().enumerate() {
                let w = &params.tensors[li * 2].data;
                let b = &params.tensors[li * 2 + 1].data;
                let w_eff: Vec<f32> = match self.mode {
                    Mode::Fp => {
                        terns.push(None);
                        w.clone()
                    }
                    Mode::Fttq => {
                        let (it, _) = quant::fttq_quantize(w, self.t_k);
                        let dense = quant::dequantize(&it, wq[li]);
                        terns.push(Some((it, dense.clone())));
                        dense
                    }
                };
                let mut next = vec![0f32; n * dims.out];
                matmul_bias(&acts[li], &w_eff, b, &mut next, n, cur, dims.out);
                if li + 1 < l {
                    for v in &mut next {
                        *v = v.max(0.0);
                    }
                }
                acts.push(next);
                cur = dims.out;
            }

            // ---- loss + dlogits ----
            let logits = &acts[l];
            let mut dlogits = vec![0f32; n * classes];
            let mut loss = 0f64;
            for i in 0..n {
                let row = &logits[i * classes..(i + 1) * classes];
                let (lse, _) = log_sum_exp(row);
                loss += (lse - row[y[i] as usize]) as f64;
                for c in 0..classes {
                    let p = (row[c] - lse).exp();
                    dlogits[i * classes + c] =
                        (p - f32::from(c == y[i] as usize)) / n as f32;
                }
            }

            // ---- backward ----
            let mut dact = dlogits;
            for li in (0..l).rev() {
                let dims = self.layers[li];
                let a_in = &acts[li];
                // grads of effective (possibly ternary) weights
                let mut dw = vec![0f32; dims.inp * dims.out];
                let mut db = vec![0f32; dims.out];
                // dw = a_in^T @ dact ; db = colsum(dact)
                for i in 0..n {
                    for o in 0..dims.out {
                        let g = dact[i * dims.out + o];
                        if g == 0.0 {
                            continue;
                        }
                        db[o] += g;
                        let row = &a_in[i * dims.inp..(i + 1) * dims.inp];
                        for (k, &aik) in row.iter().enumerate() {
                            dw[k * dims.out + o] += aik * g;
                        }
                    }
                }
                // dact_prev = dact @ w_eff^T, with ReLU mask
                if li > 0 {
                    let w_eff: Vec<f32> = match &terns[li] {
                        None => params.tensors[li * 2].data.clone(),
                        Some((_, dense)) => dense.clone(),
                    };
                    let mut dprev = vec![0f32; n * dims.inp];
                    for i in 0..n {
                        for k in 0..dims.inp {
                            let mut s = 0f32;
                            let wrow = &w_eff[k * dims.out..(k + 1) * dims.out];
                            let grow = &dact[i * dims.out..(i + 1) * dims.out];
                            for (wv, gv) in wrow.iter().zip(grow) {
                                s += wv * gv;
                            }
                            // ReLU mask of the input activation
                            if acts[li][i * dims.inp + k] <= 0.0 {
                                s = 0.0;
                            }
                            dprev[i * dims.inp + k] = s;
                        }
                    }
                    dact = dprev;
                }

                // ---- apply updates (paper Algorithm 1 STE rules) ----
                match (&self.mode, &terns[li]) {
                    (Mode::Fp, _) => {
                        let w = &mut params.tensors[li * 2].data;
                        for (wv, g) in w.iter_mut().zip(&dw) {
                            *wv -= lr * g;
                        }
                    }
                    (Mode::Fttq, Some((it, _))) => {
                        // dJ/dwq = mean over I_p of dJ/dtheta_t
                        let mut g_wq = 0f32;
                        let mut n_pos = 0usize;
                        for (s, g) in it.iter().zip(&dw) {
                            if *s > 0 {
                                g_wq += g;
                                n_pos += 1;
                            }
                        }
                        g_wq /= n_pos.max(1) as f32;
                        // latent grads: wq*g on support, g on zeros
                        let w = &mut params.tensors[li * 2].data;
                        for ((wv, g), s) in w.iter_mut().zip(&dw).zip(it) {
                            let scale = if *s != 0 { wq[li] } else { 1.0 };
                            *wv -= lr * scale * g;
                        }
                        wq[li] -= lr * g_wq;
                    }
                    (Mode::Fttq, None) => unreachable!(),
                }
                let b = &mut params.tensors[li * 2 + 1].data;
                for (bv, g) in b.iter_mut().zip(&db) {
                    *bv -= lr * g;
                }
            }
            Ok((loss / n as f64) as f32)
        }
    }

    /// out[n, o] = x[n, i] @ w[i, o] + b[o]
    fn matmul_bias(
        x: &[f32],
        w: &[f32],
        b: &[f32],
        out: &mut [f32],
        n: usize,
        i: usize,
        o: usize,
    ) {
        for r in 0..n {
            let xrow = &x[r * i..(r + 1) * i];
            let orow = &mut out[r * o..(r + 1) * o];
            orow.copy_from_slice(b);
            for (k, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wrow = &w[k * o..(k + 1) * o];
                for (ov, &wv) in orow.iter_mut().zip(wrow) {
                    *ov += xv * wv;
                }
            }
        }
    }

    fn log_sum_exp(row: &[f32]) -> (f32, usize) {
        let mut m = f32::NEG_INFINITY;
        let mut arg = 0;
        for (i, &v) in row.iter().enumerate() {
            if v > m {
                m = v;
                arg = i;
            }
        }
        let s: f32 = row.iter().map(|&v| (v - m).exp()).sum();
        (m + s.ln(), arg)
    }
}
