//! Observability regression tests (DESIGN.md §11).
//!
//! Two claims are load-bearing enough to pin here:
//! * **byte identity** — enabling obs must not change a single output
//!   byte: run metrics, scenario/sim bundles, and wire accounting are
//!   identical with tracing on and off (obs reads, never steers);
//! * **trace schema** — an enabled run emits the documented phase
//!   taxonomy with deterministic structure (names, context, export
//!   order), and the Chrome export is valid JSON covering every span.
//!
//! Obs state is process-global, so every test here serializes on one
//! lock and restores the disabled default before releasing it.

use std::collections::BTreeSet;
use std::sync::Mutex;

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::run_experiment;
use tfed::eval::RunMetrics;
use tfed::obs::trace;
use tfed::scenario::{run_scenario, ScenarioManifest};
use tfed::util::json::Json;

static OBS_LOCK: Mutex<()> = Mutex::new(());

/// Restore the default-off state (and drop any collected spans).
fn obs_off() {
    trace::set_enabled(false);
    trace::clear();
}

/// Deterministic metrics fingerprint: full JSON with the wall clock
/// zeroed (losses, accuracies, selections, byte counts all remain).
fn fingerprint(m: &RunMetrics) -> String {
    let mut m = m.clone();
    for r in &mut m.records {
        r.wall_secs = 0.0;
    }
    m.to_json().to_string()
}

fn small_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, seed);
    cfg.n_clients = 3;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 300;
    cfg.test_samples = 60;
    cfg.batch = 16;
    cfg.native_backend = true;
    cfg
}

const SIM_MANIFEST: &str = r#"
[scenario]
name = "obs_sim"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 300
test_samples = 60
seed = 7
native = true
[sim]
registered_clients = 50
"#;

#[test]
fn enabling_obs_is_byte_invisible() {
    let _g = OBS_LOCK.lock().unwrap();
    obs_off();
    let cfg = small_cfg(42);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let baseline = run_experiment(cfg.clone(), backend.as_ref()).unwrap();
    let sim_baseline =
        run_scenario(&ScenarioManifest::parse(SIM_MANIFEST).unwrap()).unwrap();

    tfed::obs::enable();
    let traced = run_experiment(cfg, backend.as_ref()).unwrap();
    let sim_traced =
        run_scenario(&ScenarioManifest::parse(SIM_MANIFEST).unwrap()).unwrap();
    obs_off();

    // same losses, accuracies, selections, and wire bytes, byte for byte
    assert_eq!(fingerprint(&baseline), fingerprint(&traced));
    // sim bundles (wall_secs zeroed by construction) match byte for byte
    assert_eq!(
        sim_baseline.to_json().to_string_pretty(),
        sim_traced.to_json().to_string_pretty()
    );
}

#[test]
fn trace_has_documented_phase_structure() {
    let _g = OBS_LOCK.lock().unwrap();
    obs_off();
    tfed::obs::enable();

    // --- one loopback run: the federated phase taxonomy ----------------
    let cfg = small_cfg(7);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    run_experiment(cfg.clone(), backend.as_ref()).unwrap();
    let events = trace::take_events();
    let names: BTreeSet<&str> = events.iter().map(|e| e.name).collect();
    for phase in [
        "round.select",
        "round.broadcast",
        "round.encode",
        "client.decode",
        "client.train",
        "client.encode",
        "client.upload",
        "round.aggregate",
        "round.eval",
    ] {
        assert!(names.contains(phase), "missing {phase} in {names:?}");
    }
    // client phases carry a client id; server phases the NO_CLIENT marker
    assert!(events
        .iter()
        .filter(|e| e.name.starts_with("client."))
        .all(|e| e.client != trace::NO_CLIENT));
    assert!(events
        .iter()
        .filter(|e| e.name.starts_with("round."))
        .all(|e| e.client == trace::NO_CLIENT));
    // both rounds are covered, and the export order is the deterministic
    // (lane, round, client, seq) key
    let rounds: BTreeSet<u32> = events.iter().map(|e| e.round).collect();
    assert!(rounds.len() >= 2, "spans cover rounds {rounds:?}");
    let keys: Vec<_> = events.iter().map(|e| (e.lane, e.round, e.client, e.seq)).collect();
    let mut sorted = keys.clone();
    sorted.sort_unstable();
    assert_eq!(keys, sorted);

    // the Chrome export parses and covers every span
    let doc = Json::parse(&trace::chrome_trace_json(&events)).unwrap();
    assert_eq!(doc.get("traceEvents").unwrap().as_arr().unwrap().len(), events.len());

    // structure (not timing) is reproducible: a second identical run
    // yields the same (name, lane, round, client, depth) sequence
    let shape = |evs: &[trace::SpanEvent]| {
        evs.iter()
            .map(|e| (e.name, e.lane, e.round, e.client, e.depth))
            .collect::<Vec<_>>()
    };
    trace::clear();
    run_experiment(cfg, backend.as_ref()).unwrap();
    assert_eq!(shape(&events), shape(&trace::take_events()));

    // --- one sim run: the virtual-time phase rides along ----------------
    trace::clear();
    run_scenario(&ScenarioManifest::parse(SIM_MANIFEST).unwrap()).unwrap();
    let sim_events = trace::take_events();
    assert!(sim_events.iter().any(|e| e.name == "sim.end_round"));
    obs_off();

    // the registry picked up the run (names only; values accumulate
    // across this process's tests)
    let text = tfed::obs::metrics::exposition();
    for metric in [
        "tfed_rounds_total",
        "tfed_clients_selected_total",
        "tfed_frames_total",
        "tfed_frame_wire_bytes",
        "tfed_layer_train_us_total",
        "tfed_sim_events_total",
    ] {
        assert!(text.contains(metric), "missing {metric} in exposition");
    }
}
