//! Transport conformance suite: one set of behavioral assertions run
//! against every `Transport` implementation — `Loopback`, `Tcp`, and the
//! virtual-time `SimTransport`.
//!
//! Contract checked for each:
//! * delivered payloads are byte-identical across transports for the
//!   same round assignment (server-derived client RNGs make the reply a
//!   pure function of the assignment);
//! * `LinkStats` data-plane accounting (bytes/frames/round-trips) agrees
//!   across transports (control-plane bytes legitimately differ: TCP has
//!   a handshake, loopback does not);
//! * a codec mismatch between the round assignment and the client's
//!   configuration is a clean error, never silent garbage;
//! * an unknown client id is a clean error;
//! * `end_round` reports virtual time from the simulator only.

use tfed::comms::{DenseGlobal, Message};
use tfed::compress::CodecSpec;
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::NativeBackend;
use tfed::coordinator::client::{ClientRuntime, ShardData};
use tfed::model::{init_params, mlp_schema};
use tfed::sim::{FleetModel, SimSpec, SimTransport};
use tfed::transport::{
    encode_data_frame, Loopback, RoundAssign, TcpBinding, TcpClient, Transport,
};
use tfed::util::rng::Pcg;

const N_CLIENTS: usize = 2;

fn shard(seed: u64, n: usize) -> ShardData {
    let mut rng = Pcg::seeded(seed);
    ShardData {
        dim: 784,
        num_classes: 10,
        x: (0..n * 784).map(|_| rng.normal() * 0.3).collect(),
        y: (0..n as u32).map(|i| i % 10).collect(),
    }
}

fn runtimes(backend: &NativeBackend) -> Vec<ClientRuntime<'_>> {
    (0..N_CLIENTS as u32)
        .map(|cid| ClientRuntime {
            client_id: cid,
            backend,
            shard: shard(cid as u64 + 1, 10 + cid as usize),
            local_epochs: 1,
            lr: 0.05,
            codec: CodecSpec::Dense,
            adversary: Default::default(),
        })
        .collect()
}

fn broadcast() -> Message {
    let schema = mlp_schema();
    let mut rng = Pcg::seeded(3);
    let params = init_params(&schema, &mut rng);
    Message::DenseGlobal(DenseGlobal {
        round: 1,
        tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
    })
}

fn assign(cid: u32, codec: CodecSpec) -> RoundAssign {
    RoundAssign { round: 1, client_id: cid, rng_seed: 55, rng_stream: cid as u64, codec }
}

/// Drive one exchange per client; return the encoded replies and the
/// per-link stats snapshot.
fn exchange_all(
    t: &dyn Transport,
) -> (Vec<Vec<u8>>, Vec<tfed::transport::LinkStats>) {
    let wire = encode_data_frame(&broadcast()).unwrap();
    let ups: Vec<Vec<u8>> = (0..N_CLIENTS)
        .map(|cid| {
            t.round_trip(cid, &assign(cid as u32, CodecSpec::Dense), &wire)
                .unwrap()
                .encode()
        })
        .collect();
    (ups, t.link_stats())
}

fn sim_over<'a>(backend: &'a NativeBackend) -> SimTransport<'a> {
    SimTransport::new(
        Loopback::new(runtimes(backend)),
        FleetModel::from_spec(&SimSpec::new(1_000, 4, 9)),
        1,
        0.0,
        0,
    )
}

#[test]
fn payloads_and_data_stats_agree_across_all_transports() {
    let backend = NativeBackend::new(mlp_schema(), 8).unwrap();

    // reference: loopback
    let lb = Loopback::new(runtimes(&backend));
    let (lb_ups, lb_stats) = exchange_all(&lb);
    assert!(lb.end_round(1).is_none(), "loopback has no virtual clock");

    // sim: byte-identical payloads + stats, plus a virtual clock
    let sim = sim_over(&backend);
    let (sim_ups, sim_stats) = exchange_all(&sim);
    assert_eq!(lb_ups, sim_ups);
    assert_eq!(lb_stats, sim_stats, "sim LinkStats must mirror loopback exactly");
    let vt = sim.end_round(1).expect("sim reports virtual time");
    assert!(vt.round_secs > 0.0);

    // tcp: same payload bytes, same data-plane counters
    let cfg = {
        let mut c = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        c.n_clients = N_CLIENTS;
        c
    };
    let binding = TcpBinding::bind("127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap().to_string();
    std::thread::scope(|s| {
        for cid in 0..N_CLIENTS as u32 {
            let addr = addr.clone();
            let backend = &backend;
            s.spawn(move || {
                let (mut client, _cfg) = TcpClient::connect(&addr, cid).unwrap();
                let runtime = ClientRuntime {
                    client_id: cid,
                    backend,
                    shard: shard(cid as u64 + 1, 10 + cid as usize),
                    local_epochs: 1,
                    lr: 0.05,
                    codec: CodecSpec::Dense,
                    adversary: Default::default(),
                };
                client.serve(&runtime).unwrap();
            });
        }
        let tcp = binding.accept_clients(N_CLIENTS, &cfg).unwrap();
        let (tcp_ups, tcp_stats) = exchange_all(&tcp);
        assert_eq!(lb_ups, tcp_ups);
        for (l, t) in lb_stats.iter().zip(&tcp_stats) {
            assert_eq!(l.up_bytes, t.up_bytes);
            assert_eq!(l.down_bytes, t.down_bytes);
            assert_eq!(l.up_frames, t.up_frames);
            assert_eq!(l.down_frames, t.down_frames);
            assert_eq!(l.round_trips, t.round_trips);
            // ctrl differs by design: TCP counts the handshake
        }
        assert!(tcp.end_round(1).is_none(), "tcp has no virtual clock");
        tcp.shutdown().unwrap();
    });
}

#[test]
fn codec_mismatch_is_rejected_by_every_transport() {
    let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
    let wire = encode_data_frame(&broadcast()).unwrap();
    let bad = assign(0, CodecSpec::Fp16); // clients are configured Dense

    let lb = Loopback::new(runtimes(&backend));
    assert!(lb.round_trip(0, &bad, &wire).is_err());

    let sim = sim_over(&backend);
    assert!(sim.round_trip(0, &bad, &wire).is_err());

    let cfg = {
        let mut c = ExperimentConfig::table2(Protocol::FedAvg, Task::MnistLike, 1);
        c.n_clients = 1;
        c
    };
    let binding = TcpBinding::bind("127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap().to_string();
    std::thread::scope(|s| {
        let backend = &backend;
        let handle = s.spawn(move || {
            let (mut client, _cfg) = TcpClient::connect(&addr, 0).unwrap();
            let runtime = ClientRuntime {
                client_id: 0,
                backend,
                shard: shard(1, 10),
                local_epochs: 1,
                lr: 0.05,
                codec: CodecSpec::Dense,
                adversary: Default::default(),
            };
            client.serve(&runtime)
        });
        let tcp = binding.accept_clients(1, &cfg).unwrap();
        assert!(tcp.round_trip(0, &bad, &wire).is_err());
        // the client rejected the round on its side too
        assert!(handle.join().unwrap().is_err());
    });
}

#[test]
fn unknown_client_is_a_clean_error() {
    let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
    let wire = encode_data_frame(&broadcast()).unwrap();
    let a = assign(99, CodecSpec::Dense);
    assert!(Loopback::new(runtimes(&backend)).round_trip(99, &a, &wire).is_err());
    assert!(sim_over(&backend).round_trip(99, &a, &wire).is_err());
}
