//! Frame-layer adversarial properties: every protocol message survives the
//! frame codec unchanged, and truncated / bit-flipped / oversized-length /
//! wrong-magic frames produce clean errors — never panics, never huge
//! allocations.

use std::io::Cursor;

use tfed::comms::{dense_update, ternary_update, Message};
use tfed::comms::{DenseGlobal, TernaryGlobal};
use tfed::model::{init_params, mlp_schema};
use tfed::quant;
use tfed::transport::{Frame, FrameError, FrameKind, HEADER_BYTES, MAX_FRAME};
use tfed::util::proptest::forall;
use tfed::util::rng::Pcg;

/// One sample message of every protocol kind, parameterized by seed.
fn sample_messages(seed: u64) -> Vec<Message> {
    let schema = mlp_schema();
    let mut rng = Pcg::seeded(seed);
    let params = init_params(&schema, &mut rng);
    let qidx = schema.quantized_indices();
    let mut patterns = Vec::new();
    let mut deltas = Vec::new();
    for &i in &qidx {
        let (it, d) = quant::fttq_quantize(&params.tensors[i].data, 0.05);
        patterns.push(it);
        deltas.push(d);
    }
    let wqs: Vec<f32> = (0..qidx.len()).map(|_| rng.next_f32() + 0.01).collect();
    let upd = ternary_update(3, 250, &qidx, &patterns, &wqs, &deltas, &params, 0.9);
    let tg = TernaryGlobal {
        round: 5,
        layers: upd.layers.iter().map(|l| (l.param_index, l.pattern.clone())).collect(),
        fp_tensors: upd.fp_tensors.clone(),
        wq_init: wqs.clone(),
    };
    let dg = DenseGlobal {
        round: 5,
        tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
    };
    vec![
        Message::TernaryUpdate(upd),
        Message::DenseUpdate(dense_update(1, 99, &params, 1.1)),
        Message::TernaryGlobal(tg),
        Message::DenseGlobal(dg),
    ]
}

#[test]
fn prop_every_message_kind_roundtrips_through_frames() {
    forall(16, |rng| {
        for msg in sample_messages(rng.next_u64()) {
            let frame = Frame::data(msg.encode());
            let wire = frame.encode().unwrap();
            assert_eq!(wire.len(), frame.wire_len());
            // slice path
            let back = Frame::decode(&wire).unwrap();
            assert_eq!(back.kind, FrameKind::Data);
            assert_eq!(Message::decode(&back.payload).unwrap(), msg);
            // stream path
            let streamed = Frame::read_from(&mut Cursor::new(&wire)).unwrap();
            assert_eq!(Message::decode(&streamed.payload).unwrap(), msg);
        }
    });
}

#[test]
fn prop_truncated_frames_error_cleanly() {
    forall(12, |rng| {
        let msgs = sample_messages(rng.next_u64());
        let msg = &msgs[rng.below(4) as usize];
        let wire = Frame::data(msg.encode()).encode().unwrap();
        // random cuts plus the boundary cases
        let mut cuts = vec![0, 1, HEADER_BYTES - 1, HEADER_BYTES, wire.len() - 1];
        for _ in 0..16 {
            cuts.push(rng.below(wire.len() as u32) as usize);
        }
        for cut in cuts {
            let err = Frame::decode(&wire[..cut]).unwrap_err();
            assert!(matches!(err, FrameError::Truncated { .. }), "cut={cut}: {err}");
            assert!(Frame::read_from(&mut Cursor::new(&wire[..cut])).is_err());
        }
    });
}

#[test]
fn prop_bit_flips_never_pass_undetected() {
    forall(12, |rng| {
        let msgs = sample_messages(rng.next_u64());
        let msg = &msgs[rng.below(4) as usize];
        let wire = Frame::data(msg.encode()).encode().unwrap();
        // every header byte, plus random payload bytes
        let mut positions: Vec<usize> = (0..HEADER_BYTES).collect();
        for _ in 0..32 {
            positions.push(rng.below(wire.len() as u32) as usize);
        }
        for pos in positions {
            let mut bad = wire.clone();
            let bit = 1u8 << (rng.below(8) as u8);
            bad[pos] ^= bit;
            // a single-bit flip must never yield the original frame back:
            // CRC-32 catches all payload bursts <= 32 bits and the header
            // fields are validated individually. The one non-error case is
            // the kind byte flipping onto another *valid* kind — the frame
            // then decodes, but visibly as a different kind.
            match Frame::decode(&bad) {
                Err(_) => {}
                Ok(f) => assert!(
                    pos == 5 && f.kind != FrameKind::Data,
                    "flip bit {bit:#04x} at byte {pos} went undetected"
                ),
            }
        }
    });
}

#[test]
fn oversized_length_is_rejected_before_allocation() {
    // hand-craft a header that claims a gigantic payload
    let mut wire = Frame::data(vec![1, 2, 3]).encode().unwrap();
    wire[6..10].copy_from_slice(&((MAX_FRAME as u32) + 1).to_le_bytes());
    assert!(matches!(Frame::decode(&wire).unwrap_err(), FrameError::Oversized { .. }));
    // the streaming reader must bail on the header alone — if it tried to
    // allocate/read the payload it would block or OOM, not error instantly
    let mut cur = Cursor::new(&wire);
    assert!(matches!(
        Frame::read_from(&mut cur).unwrap_err(),
        FrameError::Oversized { .. }
    ));
}

#[test]
fn wrong_magic_and_version_and_kind_are_typed_errors() {
    let wire = Frame::data(b"payload".to_vec()).encode().unwrap();

    let mut bad = wire.clone();
    bad[..4].copy_from_slice(b"TFED"); // message-layer magic is not frame magic
    assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::WrongMagic(_)));

    let mut bad = wire.clone();
    bad[4] = 2;
    assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::BadVersion(2)));

    let mut bad = wire.clone();
    bad[5] = 0;
    assert!(matches!(Frame::decode(&bad).unwrap_err(), FrameError::UnknownKind(0)));

    let mut bad = wire;
    bad.extend_from_slice(b"junk");
    assert!(matches!(
        Frame::decode(&bad).unwrap_err(),
        FrameError::TrailingBytes { extra: 4 }
    ));
}

#[test]
fn corrupted_payload_still_fails_message_decode_if_crc_forged() {
    // even if an attacker fixes up the CRC, the inner message codec has its
    // own magic/kind/length validation — defense in depth
    forall(8, |rng| {
        let msgs = sample_messages(rng.next_u64());
        let msg = &msgs[rng.below(4) as usize];
        let mut payload = msg.encode();
        let pos = rng.below(payload.len() as u32) as usize;
        payload[pos] ^= 0xFF;
        let wire = Frame::data(payload).encode().unwrap(); // CRC recomputed
        let frame = Frame::decode(&wire).unwrap(); // frame layer passes
        // message layer either errors or yields a *different* message —
        // never a panic
        if let Ok(got) = Message::decode(&frame.payload) {
            assert_ne!(&got, msg);
        }
    });
}
