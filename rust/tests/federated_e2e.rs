//! End-to-end federated runs through the full coordinator stack.
//!
//! Native-backend tests always run (no artifacts needed); PJRT tests no-op
//! with a note if `make artifacts` hasn't been run.

use std::sync::Arc;

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::{FaultSpec, Orchestrator};
use tfed::coordinator::run_experiment;
use tfed::runtime::manifest::default_artifacts_dir;
use tfed::runtime::Engine;

fn small_cfg(protocol: Protocol) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::table2(protocol, Task::MnistLike, 42);
    cfg.n_clients = if protocol.is_centralized() { 1 } else { 4 };
    cfg.rounds = 6;
    cfg.local_epochs = 2;
    cfg.train_samples = 600;
    cfg.test_samples = 300;
    cfg.batch = 16;
    cfg.lr = 0.1;
    cfg.native_backend = true;
    cfg
}

#[test]
fn native_fedavg_learns() {
    let cfg = small_cfg(Protocol::FedAvg);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let m = run_experiment(cfg, backend.as_ref()).unwrap();
    assert_eq!(m.records.len(), 6);
    let accs = m.acc_series();
    let first = accs.first().unwrap().1;
    let best = m.best_acc();
    assert!(best > first.max(0.3), "first={first} best={best}");
    // FedAvg moves no compressed bytes but full f32 models
    let per_round_up = m.records[0].up_bytes;
    assert!(per_round_up > 4 * 24_380, "up={per_round_up}");
}

#[test]
fn native_tfedavg_learns_and_compresses() {
    // T-FedAvg moves information through sign patterns only, so it needs
    // more rounds/epochs than FedAvg to clear the same bar (paper Fig. 6:
    // comparable converged accuracy, slower early progress on CIFAR).
    let mut cfg = small_cfg(Protocol::TFedAvg);
    cfg.rounds = 12;
    cfg.local_epochs = 5;
    cfg.lr = 0.2;
    cfg.train_samples = 2000;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let m = run_experiment(cfg.clone(), backend.as_ref()).unwrap();
    let best = m.best_acc();
    // chance is 0.10; the hardened synthetic task (DESIGN.md §3) keeps the
    // 12-round ternary budget around ~0.28 — assert clear learning, not a
    // saturation level this horizon can't reach
    assert!(best > 0.22, "best={best}");

    // compression: compare to FedAvg bytes on the identical setup
    let mut cfg_f = small_cfg(Protocol::FedAvg);
    cfg_f.rounds = 12;
    cfg_f.local_epochs = 5;
    cfg_f.lr = 0.2;
    cfg_f.train_samples = 2000;
    let mf = run_experiment(cfg_f, backend.as_ref()).unwrap();
    let ratio_up = mf.total_up_bytes() as f64 / m.total_up_bytes() as f64;
    let ratio_down = mf.total_down_bytes() as f64 / m.total_down_bytes() as f64;
    // paper §III-B: ~16x on weights; biases/overhead pull it slightly below
    assert!(ratio_up > 12.0, "up ratio {ratio_up}");
    assert!(ratio_down > 12.0, "down ratio {ratio_down}");

    // w^q factors are reported each round and finite
    let f = &m.records[0].factors;
    assert_eq!(f.len(), 3);
    assert!(f.iter().all(|v| v.is_finite()));
}

#[test]
fn native_baseline_centralized() {
    let cfg = small_cfg(Protocol::Baseline);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let m = run_experiment(cfg, backend.as_ref()).unwrap();
    assert_eq!(m.total_up_bytes(), 0);
    assert_eq!(m.total_down_bytes(), 0);
    assert!(m.best_acc() > 0.3, "best={}", m.best_acc());
}

#[test]
fn dropout_rounds_still_aggregate() {
    let cfg = small_cfg(Protocol::TFedAvg);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut orch = Orchestrator::with_faults(
        cfg,
        backend.as_ref(),
        FaultSpec { client_dropout: 0.7 },
    )
    .unwrap();
    orch.run().unwrap();
    // with 70% dropout some rounds ran with < 4 clients but all completed
    assert_eq!(orch.metrics.records.len(), 6);
    assert!(orch
        .metrics
        .records
        .iter()
        .any(|r| r.selected.len() < 4));
    assert!(orch.global().is_finite());
}

#[test]
fn non_iid_partition_flows_through() {
    let mut cfg = small_cfg(Protocol::TFedAvg);
    cfg.nc = 2;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let m = run_experiment(cfg, backend.as_ref()).unwrap();
    assert!(m.records.iter().all(|r| r.train_loss.is_finite()));
}

#[test]
fn unbalanced_shards_flow_through() {
    let mut cfg = small_cfg(Protocol::TFedAvg);
    cfg.beta = 0.2;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut orch = Orchestrator::new(cfg, backend.as_ref()).unwrap();
    let sizes = orch.shard_sizes();
    let beta = tfed::util::stats::unbalancedness(&sizes);
    assert!((beta - 0.2).abs() < 0.15, "beta={beta} sizes={sizes:?}");
    orch.run().unwrap();
}

#[test]
fn deterministic_given_seed() {
    let cfg = small_cfg(Protocol::TFedAvg);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let a = run_experiment(cfg.clone(), backend.as_ref()).unwrap();
    let b = run_experiment(cfg, backend.as_ref()).unwrap();
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.test_acc, y.test_acc);
        assert_eq!(x.up_bytes, y.up_bytes);
        assert_eq!(x.selected, y.selected);
    }
}

// ---------------------------------------------------------------------------
// PJRT end-to-end (requires artifacts)
// ---------------------------------------------------------------------------

fn pjrt_engine() -> Option<Arc<Engine>> {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping PJRT e2e: run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load(default_artifacts_dir()).unwrap()))
}

#[test]
fn pjrt_tfedavg_round_trip() {
    let Some(engine) = pjrt_engine() else { return };
    let mut cfg = small_cfg(Protocol::TFedAvg);
    cfg.native_backend = false;
    cfg.rounds = 3;
    let backend = make_backend(Some(engine), "mlp", cfg.batch, false).unwrap();
    let m = run_experiment(cfg, backend.as_ref()).unwrap();
    assert_eq!(m.records.len(), 3);
    assert!(m.records.iter().all(|r| r.train_loss.is_finite()));
    assert!(m.final_acc() > 0.1, "acc={}", m.final_acc());
    // ternary upstream is ~16x smaller than the dense model
    let up_per_client = m.records[0].up_bytes as f64 / m.records[0].selected.len() as f64;
    assert!(up_per_client < 24_380.0, "up/client={up_per_client}");
}

#[test]
fn pjrt_and_native_agree_on_fedavg_shape() {
    // not bit-identical (different batching math paths) but both learn and
    // produce comparable accuracy on the same small task
    let Some(engine) = pjrt_engine() else { return };
    let mut cfg = small_cfg(Protocol::FedAvg);
    cfg.rounds = 4;
    let native = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let m_native = run_experiment(cfg.clone(), native.as_ref()).unwrap();
    cfg.native_backend = false;
    let pjrt = make_backend(Some(engine), "mlp", cfg.batch, false).unwrap();
    let m_pjrt = run_experiment(cfg, pjrt.as_ref()).unwrap();
    let (a, b) = (m_native.best_acc(), m_pjrt.best_acc());
    assert!((a - b).abs() < 0.25, "native={a} pjrt={b}");
}
