//! End-to-end transport integration: a T-FedAvg federation over real TCP
//! sockets on localhost must produce *identical* results — final global
//! parameters and frame-layer byte counts — to the in-process loopback
//! path, and the `serve` / `client` subcommands must do the same across
//! OS processes.

mod common;

use std::io::{BufRead, BufReader, Read};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use common::run_over_tcp;
use tfed::compress::CodecSpec;
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::Orchestrator;

fn small_cfg(protocol: Protocol) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::table2(protocol, Task::MnistLike, 42);
    cfg.n_clients = 3;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 300;
    cfg.test_samples = 120;
    cfg.batch = 16;
    cfg.lr = 0.1;
    cfg.native_backend = true;
    cfg
}

#[test]
fn tcp_matches_loopback_bit_for_bit() {
    // protocol x codec grid: the paper's two protocols plus every coded
    // FedAvg variant (stochastic quant included — its rounding randomness
    // is server-seeded, so transports must still agree bit-for-bit)
    let mut cfgs = vec![
        small_cfg(Protocol::TFedAvg),
        small_cfg(Protocol::FedAvg),
    ];
    for codec in ["fp16", "quant8", "quant1", "stc:k=0.05", "ternary"] {
        let mut cfg = small_cfg(Protocol::FedAvg);
        cfg.codec = CodecSpec::parse(codec).unwrap();
        cfgs.push(cfg);
    }
    for cfg in cfgs {
        let label = format!("{:?}/{}", cfg.protocol, cfg.codec.name());
        // loopback reference
        let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
        let mut lb = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
        lb.run().unwrap();
        // real sockets
        let (tcp_metrics, tcp_global) = run_over_tcp(&cfg);

        assert_eq!(
            lb.global().l2_distance(&tcp_global),
            0.0,
            "{label}: global parameters diverged between transports"
        );
        assert_eq!(lb.metrics.records.len(), tcp_metrics.records.len());
        for (l, t) in lb.metrics.records.iter().zip(&tcp_metrics.records) {
            assert_eq!(l.up_bytes, t.up_bytes, "{label} round {}", l.round);
            assert_eq!(l.down_bytes, t.down_bytes, "{label} round {}", l.round);
            assert_eq!(l.up_frames, t.up_frames);
            assert_eq!(l.down_frames, t.down_frames);
            assert_eq!(l.selected, t.selected);
            assert_eq!(l.test_acc.to_bits(), t.test_acc.to_bits());
            assert_eq!(l.train_loss.to_bits(), t.train_loss.to_bits());
        }
        // one data frame each way per selected client per round
        let sel: u64 = lb.metrics.records.iter().map(|r| r.selected.len() as u64).sum();
        assert_eq!(lb.metrics.total_up_frames(), sel);
        assert_eq!(lb.metrics.total_down_frames(), sel);
    }
}

#[test]
fn worker_pool_width_does_not_change_results() {
    let cfg = small_cfg(Protocol::TFedAvg);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut serial = Orchestrator::new(cfg.clone(), backend.as_ref()).unwrap();
    serial.set_workers(1);
    serial.run().unwrap();
    let mut wide = Orchestrator::new(cfg, backend.as_ref()).unwrap();
    wide.set_workers(8);
    wide.run().unwrap();
    assert_eq!(serial.global().l2_distance(wide.global()), 0.0);
    for (a, b) in serial.metrics.records.iter().zip(&wide.metrics.records) {
        assert_eq!(a.up_bytes, b.up_bytes);
        assert_eq!(a.test_acc.to_bits(), b.test_acc.to_bits());
    }
}

// ---------------------------------------------------------------------------
// true multi-process run via the serve/client subcommands
// ---------------------------------------------------------------------------

/// Kill a child process when the test panics or finishes.
struct Reaper(Child);

impl Drop for Reaper {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

fn wait_timeout(child: &mut Child, limit: Duration, who: &str) -> std::process::ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(status) = child.try_wait().unwrap() {
            return status;
        }
        if t0.elapsed() > limit {
            panic!("{who} did not exit within {limit:?}");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn serve_and_client_subcommands_run_a_round_across_processes() {
    let bin = env!("CARGO_BIN_EXE_tfed");
    let server = Command::new(bin)
        .args([
            "serve",
            "--listen",
            "127.0.0.1:0",
            "--protocol",
            "tfedavg",
            "--clients",
            "2",
            "--rounds",
            "2",
            "--epochs",
            "1",
            "--train-samples",
            "300",
            "--test-samples",
            "100",
            "--batch",
            "16",
            "--native",
            "--quiet",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn serve");
    let mut server = Reaper(server);
    let mut reader = BufReader::new(server.0.stdout.take().unwrap());

    // the serve subcommand prints its bound address before blocking
    let addr = {
        let mut line = String::new();
        loop {
            line.clear();
            let n = reader.read_line(&mut line).expect("read server stdout");
            assert!(n > 0, "server exited before printing its listen address");
            if let Some(rest) = line.strip_prefix("listening on ") {
                break rest.split_whitespace().next().unwrap().to_string();
            }
        }
    };

    let mut clients: Vec<Reaper> = (0..2)
        .map(|cid| {
            Reaper(
                Command::new(bin)
                    .args([
                        "client",
                        "--connect",
                        &addr,
                        "--client-id",
                        &cid.to_string(),
                        "--quiet",
                    ])
                    .stdout(Stdio::piped())
                    .stderr(Stdio::null())
                    .spawn()
                    .expect("spawn client"),
            )
        })
        .collect();

    let limit = Duration::from_secs(120);
    for (i, c) in clients.iter_mut().enumerate() {
        let status = wait_timeout(&mut c.0, limit, &format!("client {i}"));
        assert!(status.success(), "client {i} failed: {status}");
    }
    let status = wait_timeout(&mut server.0, limit, "server");
    assert!(status.success(), "server failed: {status}");

    let mut out = String::new();
    reader.read_to_string(&mut out).unwrap();
    assert!(out.contains("final acc"), "server summary missing:\n{out}");
    assert!(out.contains("upstream"), "server summary missing upstream:\n{out}");

    // the clients reported the rounds they served
    for (i, c) in clients.iter_mut().enumerate() {
        let mut cout = String::new();
        c.0.stdout.take().unwrap().read_to_string(&mut cout).unwrap();
        assert!(
            cout.contains("served 2 rounds"),
            "client {i} output unexpected:\n{cout}"
        );
    }
}
