//! Codec-conformance property suite, run against every registered codec
//! (ISSUE 2 satellite): roundtrip identity for lossless codecs, bounded
//! error + unbiasedness-in-expectation for lossy ones, wire-byte
//! accounting cross-checked against the transport layer's `LinkStats`,
//! and corrupt-payload rejection with typed errors.

use tfed::comms::{CodedGlobal, Message};
use tfed::compress::{
    self, build_named, codec_names, CodecError, CodecSpec, Compressor,
};
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::Orchestrator;
use tfed::model::ParamSet;
use tfed::transport::{encode_data_frame, HEADER_BYTES};
use tfed::util::proptest::forall;
use tfed::util::rng::Pcg;

fn every_codec() -> Vec<Box<dyn Compressor>> {
    codec_names().iter().map(|n| build_named(n).unwrap()).collect()
}

// ---------------------------------------------------------------------------
// tensor-level properties
// ---------------------------------------------------------------------------

#[test]
fn conformance_decode_always_returns_numel_values() {
    forall(48, |rng| {
        let n = rng.below(3000) as usize;
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        for codec in every_codec() {
            let enc = codec.encode_tensor(&v, rng).unwrap();
            let dec = codec.decode_tensor(&enc, n).unwrap();
            assert_eq!(dec.len(), n, "{}", codec.name());
            assert!(
                dec.iter().all(|x| x.is_finite()),
                "{} produced non-finite output",
                codec.name()
            );
        }
    });
}

#[test]
fn conformance_lossless_codecs_roundtrip_identically() {
    forall(48, |rng| {
        let n = 1 + rng.below(2000) as usize;
        let v: Vec<f32> = (0..n).map(|_| rng.normal() * 10.0).collect();
        let dense = build_named("dense").unwrap();
        let dec = dense
            .decode_tensor(&dense.encode_tensor(&v, rng).unwrap(), n)
            .unwrap();
        for (a, b) in dec.iter().zip(&v) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    });
}

#[test]
fn conformance_lossy_error_bounds() {
    forall(48, |rng| {
        let n = 1 + rng.below(2000) as usize;
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let lo = v.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        let hi = v.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let max_abs = v.iter().fold(0f32, |a, &b| a.max(b.abs()));
        for codec in every_codec() {
            let enc = codec.encode_tensor(&v, rng).unwrap();
            let dec = codec.decode_tensor(&enc, n).unwrap();
            // per-element bound, specific to each codec family
            let bound = match codec.spec() {
                CodecSpec::Dense => 0.0,
                CodecSpec::Fp16 => max_abs / 2048.0 + 1e-7,
                CodecSpec::Quant { bits } => {
                    (hi - lo) / ((1u32 << bits) - 1) as f32 * 1.0001 + 1e-6
                }
                // sparsification error is bounded by the largest
                // magnitude it may zero out or rescale
                CodecSpec::Ternary | CodecSpec::Stc { .. } => 2.0 * max_abs + 1e-6,
            };
            for (d, x) in dec.iter().zip(&v) {
                assert!(
                    (d - x).abs() <= bound,
                    "{}: |{d} - {x}| > {bound}",
                    codec.name()
                );
            }
        }
    });
}

#[test]
fn conformance_stochastic_quant_is_unbiased() {
    // E[decode(encode(v))] = v is the property convergence proofs lean on
    let v = [0.31f32, -0.87, 0.04, 0.66, -0.12, 0.95, -0.44, 0.20];
    for bits in [1u8, 4, 8] {
        let codec = compress::build(CodecSpec::Quant { bits }).unwrap();
        let trials = 2000u64;
        let mut acc = [0f64; 8];
        for t in 0..trials {
            let mut rng = Pcg::seeded(7_000 + t);
            let dec = codec
                .decode_tensor(&codec.encode_tensor(&v, &mut rng).unwrap(), v.len())
                .unwrap();
            for (a, d) in acc.iter_mut().zip(&dec) {
                *a += *d as f64;
            }
        }
        let step = (0.95 - (-0.87)) as f64 / ((1u32 << bits) - 1) as f64;
        let tol = step / (trials as f64).sqrt() * 4.0 + 1e-4;
        for (a, x) in acc.iter().zip(&v) {
            let mean = a / trials as f64;
            assert!(
                (mean - *x as f64).abs() < tol,
                "quant{bits}: E[{x}] -> {mean} (tol {tol})"
            );
        }
    }
}

#[test]
fn conformance_corrupt_payloads_rejected_with_typed_errors() {
    forall(24, |rng| {
        let n = 1 + rng.below(800) as usize;
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        for codec in every_codec() {
            let enc = codec.encode_tensor(&v, rng).unwrap();
            // every truncation is a typed error, never a panic
            for cut in 0..enc.len().min(24) {
                assert!(
                    codec.decode_tensor(&enc[..cut], n).is_err(),
                    "{} accepted a {cut}-byte prefix",
                    codec.name()
                );
            }
            if !enc.is_empty() {
                assert!(codec.decode_tensor(&enc[..enc.len() - 1], n).is_err());
            }
            // wrong element count against a valid payload: codecs whose
            // payload length is a function of numel must catch it here
            // (stc/quant get it at the ParamSet layer via the schema)
            if matches!(
                codec.spec(),
                CodecSpec::Dense | CodecSpec::Fp16 | CodecSpec::Ternary
            ) {
                assert!(codec.decode_tensor(&enc, n + 7).is_err(), "{}", codec.name());
            }
            // random bit flip: either a typed CodecError or a well-formed
            // tensor — decode must stay total
            let mut bad = enc.clone();
            if !bad.is_empty() {
                let pos = rng.below(bad.len() as u32) as usize;
                bad[pos] ^= 1 << rng.below(8);
                match codec.decode_tensor(&bad, n) {
                    Ok(dec) => assert_eq!(dec.len(), n),
                    Err(
                        CodecError::Truncated { .. }
                        | CodecError::LengthMismatch { .. }
                        | CodecError::Corrupt(_)
                        | CodecError::BadParams(_)
                        | CodecError::UnknownCodec(_),
                    ) => {}
                }
            }
        }
    });
}

#[test]
fn conformance_paramset_roundtrip_against_model_schema() {
    let schema = tfed::model::mlp_schema();
    let mut rng = Pcg::seeded(42);
    let params = tfed::model::init_params(&schema, &mut rng);
    let shapes: Vec<Vec<usize>> = schema.params.iter().map(|p| p.shape.clone()).collect();
    for codec in every_codec() {
        let upd = compress::compress(codec.as_ref(), &params, &mut rng).unwrap();
        assert_eq!(upd.tensors.len(), shapes.len());
        assert!(upd.wire_bytes() > 0);
        let back = compress::decompress(codec.as_ref(), &upd, &shapes).unwrap();
        back.check(&schema).unwrap();
        assert!(back.is_finite());
    }
}

// ---------------------------------------------------------------------------
// wire accounting: measured LinkStats vs the codec's own byte math
// ---------------------------------------------------------------------------

fn coded_cfg(codec: &str) -> ExperimentConfig {
    let spec = CodecSpec::parse(codec).unwrap();
    let mut cfg = ExperimentConfig::table2(Protocol::for_codec(spec), Task::MnistLike, 42);
    cfg.codec = spec;
    cfg.n_clients = 2;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 200;
    cfg.test_samples = 80;
    cfg.batch = 16;
    cfg.lr = 0.1;
    cfg.native_backend = true;
    cfg
}

/// Run a tiny federation for one codec; returns (metrics, total stats,
/// per-round down-frame wire size predicted from the initial global).
fn run_codec(codec: &str) -> (tfed::eval::RunMetrics, tfed::transport::LinkStats, ParamSet) {
    let cfg = coded_cfg(codec);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut orch = Orchestrator::new(cfg, backend.as_ref()).unwrap();
    let initial_global = orch.global().clone();
    orch.run().unwrap();
    let stats = orch.transport_stats();
    (orch.metrics.clone(), stats, initial_global)
}

#[test]
fn wire_bytes_match_link_stats_for_every_codec() {
    for codec in ["dense", "fp16", "quant8", "quant1", "stc:k=0.01", "ternary"] {
        let (metrics, stats, _) = run_codec(codec);
        // the per-round records are snapshots of the same LinkStats the
        // transport reports — totals must agree exactly
        assert_eq!(metrics.total_up_bytes(), stats.up_bytes, "{codec}");
        assert_eq!(metrics.total_down_bytes(), stats.down_bytes, "{codec}");
        assert_eq!(metrics.total_up_frames(), stats.up_frames, "{codec}");
        assert_eq!(metrics.total_down_frames(), stats.down_frames, "{codec}");
        assert!(stats.up_bytes > 0 && stats.down_bytes > 0, "{codec}");
    }
}

#[test]
fn deterministic_codec_round_bytes_predictable_from_message_encoding() {
    // fp16 is deterministic, so the round-1 broadcast can be re-encoded
    // from the orchestrator's initial global and must measure exactly what
    // LinkStats saw per client
    let (metrics, _, global) = run_codec("fp16");
    let codec = build_named("fp16").unwrap();
    let mut rng = Pcg::seeded(0); // fp16 ignores the rng
    let update = compress::compress(codec.as_ref(), &global, &mut rng).unwrap();
    let msg = Message::CodedGlobal(CodedGlobal { round: 1, update });
    let frame = encode_data_frame(&msg).unwrap();
    let r1 = &metrics.records[0];
    let per_client = r1.down_bytes / r1.selected.len() as u64;
    assert_eq!(per_client, frame.len() as u64);
    assert_eq!(frame.len(), msg.encode().len() + HEADER_BYTES);
}

#[test]
fn measured_compression_ratios_are_ordered() {
    let dense_up = run_codec("dense").0.total_up_bytes() as f64;
    let ratio = |codec: &str| dense_up / run_codec(codec).0.total_up_bytes() as f64;

    let fp16 = ratio("fp16");
    assert!((1.8..=2.1).contains(&fp16), "fp16 ratio {fp16}");
    let q8 = ratio("quant8");
    assert!((3.2..=4.2).contains(&q8), "quant8 ratio {q8}");
    let q1 = ratio("quant1");
    assert!(q1 > 12.0, "quant1 ratio {q1}");
    let tern = ratio("ternary");
    assert!(tern > 12.0, "ternary ratio {tern}");
    let stc = ratio("stc:k=0.01");
    // 1% density with ~9-bit positions+signs: far beyond ternary's 16x
    assert!(stc > 25.0, "stc ratio {stc}");
}

#[test]
fn coded_federations_learn() {
    // every codec must still produce a model that trains (sanity against
    // a codec that decodes to garbage while staying wire-consistent)
    for codec in ["fp16", "quant8", "stc:k=0.25"] {
        let mut cfg = coded_cfg(codec);
        cfg.rounds = 6;
        cfg.local_epochs = 2;
        cfg.lr = 0.15;
        cfg.train_samples = 600;
        cfg.test_samples = 300;
        let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
        let mut orch = Orchestrator::new(cfg, backend.as_ref()).unwrap();
        orch.run().unwrap();
        let best = orch.metrics.best_acc();
        assert!(best > 0.15, "{codec}: best acc {best} (chance is 0.10)");
    }
}
