//! Property tests over coordinator invariants (native backend — fast).

use tfed::comms::{pack_ternary, unpack_dequantize, unpack_ternary, Message};
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::run_experiment;
use tfed::data::partition::{partition, PartitionSpec};
use tfed::data::synth::Dataset;
use tfed::model::{init_params, mlp_schema};
use tfed::quant;
use tfed::util::proptest::forall;
use tfed::util::rng::Pcg;

#[test]
fn prop_codec_roundtrip_any_pattern() {
    forall(256, |rng| {
        let n = rng.below(10_000) as usize;
        let it: Vec<i8> = (0..n).map(|_| rng.below(3) as i8 - 1).collect();
        let p = pack_ternary(&it);
        assert_eq!(unpack_ternary(&p).unwrap(), it);
        let wq = rng.next_f32() + 0.001;
        let dense = unpack_dequantize(&p, wq).unwrap();
        for (d, &s) in dense.iter().zip(&it) {
            assert_eq!(*d, wq * s as f32);
        }
    });
}

#[test]
fn prop_partition_is_exact_cover_under_all_specs() {
    forall(48, |rng| {
        let n = 200 + rng.below(3000) as usize;
        let data = Dataset {
            dim: 1,
            num_classes: 10,
            features: vec![0.0; n],
            labels: (0..n as u32).map(|i| i % 10).collect(),
        };
        // half the cases exercise the nc/beta splitters, half Dirichlet
        let dirichlet = rng.next_f64() < 0.5;
        let spec = PartitionSpec {
            n_clients: 1 + rng.below(30) as usize,
            nc: 1 + rng.below(12) as usize,
            beta: if dirichlet { 1.0 } else { 0.1 + 0.9 * rng.next_f64() },
            alpha: if dirichlet { 0.05 + 2.0 * rng.next_f64() } else { 0.0 },
            seed: rng.next_u64(),
        };
        let p = partition(&data, &spec).unwrap();
        assert!(p.is_exact_cover(n), "spec {spec:?}");
        assert_eq!(p.shards.len(), spec.n_clients);
        assert!(p.shards.iter().all(|s| !s.indices.is_empty()));
    });
}

#[test]
fn prop_requantize_always_ternary_and_deterministic() {
    forall(64, |rng| {
        let n = 1 + rng.below(5000) as usize;
        let v: Vec<f32> = (0..n).map(|_| rng.normal() * (rng.next_f32() + 0.01)).collect();
        let a = quant::server_requantize(&v, 0.05);
        let b = quant::server_requantize(&v, 0.05);
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| (-1..=1).contains(&s)));
    });
}

#[test]
fn prop_quantize_dequantize_reduces_or_preserves_support() {
    // every nonzero of theta_t corresponds to |theta_s| > delta
    forall(64, |rng| {
        let n = 10 + rng.below(2000) as usize;
        let v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let (it, delta) = quant::fttq_quantize(&v, 0.05);
        let s = quant::scale(&v);
        for (x, &sgn) in s.iter().zip(&it) {
            if sgn != 0 {
                assert!(x.abs() > delta - 1e-6);
                assert_eq!(x.signum() as i8, sgn);
            } else {
                assert!(x.abs() <= delta + 1e-6);
            }
        }
    });
}

#[test]
fn prop_message_encode_decode_identity() {
    forall(64, |rng| {
        let schema = mlp_schema();
        let mut prng = Pcg::seeded(rng.next_u64());
        let params = init_params(&schema, &mut prng);
        let qidx = schema.quantized_indices();
        let mut patterns = Vec::new();
        let mut deltas = Vec::new();
        for &i in &qidx {
            let (it, d) = quant::fttq_quantize(&params.tensors[i].data, 0.05);
            patterns.push(it);
            deltas.push(d);
        }
        let wqs: Vec<f32> = (0..qidx.len()).map(|_| rng.next_f32()).collect();
        let upd = tfed::comms::ternary_update(
            rng.below(100),
            rng.below(10_000) as u64,
            &qidx,
            &patterns,
            &wqs,
            &deltas,
            &params,
            rng.next_f32(),
        );
        let msg = Message::TernaryUpdate(upd.clone());
        match Message::decode(&msg.encode()).unwrap() {
            Message::TernaryUpdate(got) => assert_eq!(got, upd),
            _ => panic!("kind changed"),
        }
    });
}

#[test]
fn prop_federated_run_never_produces_nan() {
    // tiny sweeps across protocol / nc / beta / participation: the global
    // model and all metrics stay finite
    forall(6, |rng| {
        let protocol = if rng.next_f32() < 0.5 { Protocol::TFedAvg } else { Protocol::FedAvg };
        let mut cfg = ExperimentConfig::table2(protocol, Task::MnistLike, rng.next_u64());
        cfg.n_clients = 3;
        cfg.rounds = 2;
        cfg.local_epochs = 1;
        cfg.train_samples = 300;
        cfg.test_samples = 120;
        cfg.batch = 16;
        cfg.lr = 0.05;
        cfg.nc = 1 + rng.below(10) as usize;
        cfg.beta = 0.2 + 0.8 * rng.next_f64();
        cfg.participation = 0.5 + 0.5 * rng.next_f64();
        cfg.native_backend = true;
        let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
        let m = run_experiment(cfg, backend.as_ref()).unwrap();
        for r in &m.records {
            assert!(r.train_loss.is_finite());
            if r.evaluated {
                assert!(r.test_acc.is_finite() && (0.0..=1.0).contains(&r.test_acc));
            }
        }
    });
}

#[test]
fn prop_upstream_bytes_scale_with_selected_clients() {
    forall(4, |rng| {
        let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, rng.next_u64());
        cfg.n_clients = 6;
        cfg.rounds = 1;
        cfg.local_epochs = 1;
        cfg.train_samples = 600;
        cfg.test_samples = 60;
        cfg.batch = 16;
        cfg.native_backend = true;
        cfg.participation = 0.5;
        let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
        let m_half = run_experiment(cfg.clone(), backend.as_ref()).unwrap();
        cfg.participation = 1.0;
        let m_full = run_experiment(cfg, backend.as_ref()).unwrap();
        let per_client_half = m_half.records[0].up_bytes as f64
            / m_half.records[0].selected.len() as f64;
        let per_client_full = m_full.records[0].up_bytes as f64
            / m_full.records[0].selected.len() as f64;
        // per-client payload is constant; totals scale with participation
        assert!((per_client_half - per_client_full).abs() < 1.0);
        assert_eq!(m_full.records[0].selected.len(), 6);
        assert_eq!(m_half.records[0].selected.len(), 3);
    });
}
