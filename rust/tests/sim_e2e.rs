//! End-to-end virtual-time simulator runs (native backend): determinism
//! at any worker count, event-trace reproducibility under adversarial
//! call orders, and the checked-in `sim_fleet.toml` acceptance scenario
//! (100k registered clients, multi-round, byte-identical bundles).

mod common;

use common::fingerprint;
use tfed::comms::{DenseGlobal, Message};
use tfed::compress::CodecSpec;
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::availability::AvailabilityModel;
use tfed::coordinator::backend::{make_backend, NativeBackend};
use tfed::coordinator::client::{ClientRuntime, ShardData};
use tfed::coordinator::server::Orchestrator;
use tfed::model::{init_params, mlp_schema};
use tfed::scenario::{run_scenario, ScenarioManifest};
use tfed::sim::{FleetModel, SimSpec, SimTransport};
use tfed::transport::{encode_data_frame, Loopback, RoundAssign, Transport};
use tfed::util::rng::Pcg;

fn sim_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, seed);
    cfg.n_clients = 4;
    cfg.rounds = 3;
    cfg.local_epochs = 1;
    cfg.batch = 16;
    cfg.train_samples = 400;
    cfg.test_samples = 100;
    cfg.native_backend = true;
    cfg
}

#[test]
fn sim_runs_are_identical_at_any_worker_count() {
    let cfg = sim_cfg(7);
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let availability =
        AvailabilityModel::new(0.1, Vec::new(), 0.2, 10_000).unwrap(); // virtual stragglers
    let run = |workers: usize| {
        let mut orch = Orchestrator::with_sim(
            cfg.clone(),
            backend.as_ref(),
            availability.clone(),
            SimSpec::new(50_000, 8, 21),
        )
        .unwrap();
        orch.set_workers(workers);
        orch.run().unwrap();
        orch.metrics.clone()
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(fingerprint(&a), fingerprint(&b));
    // the virtual clock actually advanced, and cohorts came from the
    // registered population (ids beyond the 4 data shards)
    assert!(a.total_sim_secs() > 0.0);
    assert!(a
        .records
        .iter()
        .any(|r| r.selected.iter().any(|&rid| rid >= cfg.n_clients)));
    for r in &a.records {
        assert!(r.sim_secs > 0.0, "round {} has no virtual time", r.round);
        assert!(r.selected.iter().all(|&rid| rid < 50_000));
    }
}

#[test]
fn centralized_protocols_reject_the_simulator() {
    let mut cfg = ExperimentConfig::table2(Protocol::Baseline, Task::MnistLike, 1);
    cfg.native_backend = true;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let r = Orchestrator::with_sim(
        cfg,
        backend.as_ref(),
        AvailabilityModel::always_on(),
        SimSpec::new(100, 10, 1),
    );
    assert!(r.is_err());
}

#[test]
fn event_trace_is_independent_of_exchange_order() {
    let backend = NativeBackend::new(mlp_schema(), 8).unwrap();
    let make_sim = || {
        let runtimes: Vec<ClientRuntime> = (0..2u32)
            .map(|cid| ClientRuntime {
                client_id: cid,
                backend: &backend,
                shard: ShardData {
                    dim: 784,
                    num_classes: 10,
                    x: {
                        let mut rng = Pcg::seeded(cid as u64 + 1);
                        (0..12 * 784).map(|_| rng.normal() * 0.3).collect()
                    },
                    y: (0..12u32).map(|i| i % 10).collect(),
                },
                local_epochs: 1,
                lr: 0.05,
                codec: CodecSpec::Dense,
                adversary: Default::default(),
            })
            .collect();
        SimTransport::new(
            Loopback::new(runtimes),
            FleetModel::from_spec(&SimSpec::new(10_000, 4, 5)),
            1,
            0.3,
            5_000,
        )
    };
    let schema = mlp_schema();
    let mut rng = Pcg::seeded(2);
    let params = init_params(&schema, &mut rng);
    let wire = encode_data_frame(&Message::DenseGlobal(DenseGlobal {
        round: 1,
        tensors: params.tensors.iter().map(|t| t.data.clone()).collect(),
    }))
    .unwrap();
    // four registered clients mapped onto the two shards, exchanged in
    // opposite orders on the two instances
    let rids: [u32; 4] = [11, 4242, 8080, 9001];
    let assign = |rid: u32| RoundAssign {
        round: 1,
        client_id: rid,
        rng_seed: 5,
        rng_stream: rid as u64,
        codec: CodecSpec::Dense,
    };
    let a = make_sim();
    for &rid in &rids {
        a.round_trip(rid as usize % 2, &assign(rid), &wire).unwrap();
    }
    let va = a.end_round(1).unwrap();
    let b = make_sim();
    for &rid in rids.iter().rev() {
        b.round_trip(rid as usize % 2, &assign(rid), &wire).unwrap();
    }
    let vb = b.end_round(1).unwrap();
    assert_eq!(a.event_log(), b.event_log());
    assert_eq!(va, vb);
    assert_eq!(a.clock_us(), b.clock_us());
    // the trace is sorted by (time, client) and covers the cohort
    let log = a.event_log();
    assert_eq!(log.len(), 4);
    assert!(log.windows(2).all(|w| (w[0].time_us, w[0].client)
        <= (w[1].time_us, w[1].client)));
}

#[test]
fn sim_fleet_manifest_meets_the_acceptance_bar() {
    let manifest =
        ScenarioManifest::load("../examples/scenarios/sim_fleet.toml").unwrap();
    let sim = manifest.sim.as_ref().expect("sim_fleet.toml declares [sim]");
    assert!(sim.registered >= 100_000, "acceptance: >= 100k registered clients");
    assert!(manifest.base.rounds >= 2, "acceptance: multi-round");
    let grid = manifest.grid().unwrap();
    assert_eq!(grid.len(), 5, "five codecs under comparison");
    assert!(grid.iter().any(|c| c.cfg.protocol == Protocol::TFedAvg));

    let started = std::time::Instant::now();
    let first = run_scenario(&manifest).unwrap();
    let second = run_scenario(&manifest).unwrap();
    let elapsed = started.elapsed();
    // two full runs; the acceptance bar is < 10 s for one (keep slack
    // for slow CI machines rather than flake)
    assert!(elapsed.as_secs() < 60, "two sim_fleet runs took {elapsed:?}");

    // byte-identical bundles, run over run (wall time is zeroed for sim
    // cells by the runner; everything else is deterministic)
    assert_eq!(
        first.to_json().to_string_pretty(),
        second.to_json().to_string_pretty()
    );

    let mut saw_straggler = false;
    for cell in &first.cells {
        let s = cell.sim.as_ref().expect("sim cells carry a sim summary");
        assert!(s.total_sim_secs > 0.0, "{}: no virtual time", cell.label);
        assert!(s.rounds_per_virtual_hour > 0.0);
        assert_eq!(s.target_acc, Some(0.3));
        for r in &cell.metrics.records {
            assert_eq!(r.wall_secs, 0.0, "sim bundles must not leak wall time");
            assert!(r.sim_secs > 0.0);
            saw_straggler |= r.straggler_delay_ms > 0;
        }
    }
    // 10% straggler probability over 5 cells × 3 rounds × 16 clients:
    // the virtual tail must have bitten somewhere
    assert!(saw_straggler, "no virtual straggler delay was accounted");
}
