//! End-to-end contracts for the run ledger (DESIGN.md §14):
//! durability (torn-final-record recovery), determinism (rerun
//! byte-identity with wall clocks quarantined, `--jobs`-independent
//! append order), the history/query/diff renderings, and the diff
//! perf gate's exit-code behavior through the real binary.

use std::path::PathBuf;

use tfed::obs::lens;
use tfed::obs::store::{self, Ledger, Record, RecordKind};
use tfed::scenario::{run_scenario, run_scenario_jobs, ScenarioManifest, ScenarioResults};

const MANIFEST: &str = r#"
[scenario]
name = "store-e2e"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 240
test_samples = 60
seed = 5
native = true
[sweep]
seeds = [5, 6]
"#;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tfed_store_e2e_{}_{name}.tfed", std::process::id()))
}

fn fresh(name: &str) -> PathBuf {
    let p = tmp(name);
    let _ = std::fs::remove_file(&p);
    p
}

fn run_grid() -> ScenarioResults {
    run_scenario(&ScenarioManifest::parse(MANIFEST).unwrap()).unwrap()
}

/// The ledger's determinism fingerprint: every payload except the
/// wall-clock quarantine, in order.
fn stable_payloads(records: &[Record]) -> Vec<(RecordKind, Vec<u8>)> {
    records
        .iter()
        .filter(|r| !r.kind.is_wall_clock())
        .map(|r| (r.kind, r.payload.clone()))
        .collect()
}

#[test]
fn rerun_appends_are_byte_identical_outside_timestamps() {
    let path = fresh("rerun");
    let p = path.to_str().unwrap();
    let first = run_grid();
    let second = run_grid();
    assert_eq!(store::append_cells(p, &first.cells).unwrap(), 2);
    assert_eq!(store::append_cells(p, &second.cells).unwrap(), 2);

    let scanned = store::read_ledger(&path).unwrap();
    assert!(scanned.damage.is_none());
    // two appends of the same grid → the record stream splits exactly
    // in half, and the stable halves match byte for byte
    let n = scanned.records.len();
    assert_eq!(n % 2, 0);
    let a = stable_payloads(&scanned.records[..n / 2]);
    let b = stable_payloads(&scanned.records[n / 2..]);
    assert!(!a.is_empty());
    assert_eq!(a, b, "rerun produced different stable record bytes");
    // the wall clock lives only in the quarantine: no stable payload
    // mentions it, and every run carries exactly one timestamp record
    for (_, payload) in &a {
        let text = String::from_utf8(payload.clone()).unwrap();
        assert!(!text.contains("wall_secs"), "wall clock leaked: {text}");
    }
    let timestamps =
        scanned.records.iter().filter(|r| r.kind == RecordKind::Timestamp).count();
    assert_eq!(timestamps, 4);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn jobs_parallelism_preserves_append_order() {
    let m = ScenarioManifest::parse(MANIFEST).unwrap();
    let sequential = run_scenario_jobs(&m, 1).unwrap();
    let parallel = run_scenario_jobs(&m, 2).unwrap();
    let (p1, p2) = (fresh("jobs1"), fresh("jobs2"));
    store::append_cells(p1.to_str().unwrap(), &sequential.cells).unwrap();
    store::append_cells(p2.to_str().unwrap(), &parallel.cells).unwrap();
    let a = stable_payloads(&store::read_ledger(&p1).unwrap().records);
    let b = stable_payloads(&store::read_ledger(&p2).unwrap().records);
    assert_eq!(a, b, "--jobs changed ledger append order or content");
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);
}

#[test]
fn torn_final_record_recovers_and_keeps_history_readable() {
    let path = fresh("torn");
    let p = path.to_str().unwrap();
    let results = run_grid();
    store::append_cells(p, &results.cells).unwrap();
    let intact = store::read_ledger(&path).unwrap().records.len();

    // simulate a crash mid-append: the file ends inside the last record
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();

    // the reader still serves the intact prefix, with typed damage; the
    // torn record was the grid's final timestamp, so both runs list
    let view = lens::load(p).unwrap();
    assert!(view.damage.as_deref().unwrap().contains("torn tail"));
    assert_eq!(view.entries.len(), 2);
    let hist = lens::render_history(&view, &lens::HistoryFilter::default());
    assert!(hist.contains("warning: torn tail"));

    // reopening truncates the tear; the next append decodes cleanly
    store::append_cells(p, &results.cells).unwrap();
    let healed = store::read_ledger(&path).unwrap();
    assert!(healed.damage.is_none(), "tear survived reopen: {:?}", healed.damage);
    assert_eq!(healed.records.len(), (intact - 1) + intact);
    assert_eq!(lens::load(p).unwrap().entries.len(), 4);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn history_query_and_diff_render_the_recorded_grid() {
    let path = fresh("render");
    let p = path.to_str().unwrap();
    let results = run_grid();
    store::append_cells(p, &results.cells).unwrap();
    store::append_cells(p, &results.cells).unwrap();
    let view = lens::load(p).unwrap();
    assert_eq!(view.entries.len(), 4);

    // history: all four runs — each seed's cell listed once per append
    let hist = lens::render_history(&view, &lens::HistoryFilter::default());
    assert_eq!(hist.matches("seed=5 ").count(), 2);
    assert_eq!(hist.matches("seed=6 ").count(), 2);
    // seed filter narrows to that seed's rerun pair
    let hist5 = lens::render_history(
        &view,
        &lens::HistoryFilter { seed: Some(5), ..Default::default() },
    );
    assert!(hist5.contains("seed=5"));
    assert!(!hist5.contains("seed=6"));

    // query: identity, totals, compression pricing, per-round CSV
    let q = lens::render_entry(lens::find(&view, "1").unwrap());
    assert!(q.contains("model=mlp"));
    assert!(q.contains("codec=ternary"));
    assert!(q.contains("x vs dense fp32"));
    assert!(q.contains("round,train_loss,test_acc"));
    assert!(q.contains("recorded   : unix_ms"));

    // seq 1 and 3 are the same seed-5 cell from each append: zero drift
    let t = lens::DiffThresholds {
        max_acc_drop: 0.02,
        max_mb_grow_pct: 10.0,
        max_perf_drop_pct: 20.0,
    };
    let d = lens::diff(&view, "1", "3", &t).unwrap();
    assert!(d.breaches.is_empty(), "identical reruns breached: {:?}", d.breaches);
    assert!(d.text.contains("zero drift"));
    // the rerun-shared id resolves via occurrence selectors too
    let id = view.entries[0].id().to_string();
    let d = lens::diff(&view, &format!("{id}@0"), &format!("{id}@1"), &t).unwrap();
    assert!(d.text.contains("zero drift"));
    let _ = std::fs::remove_file(&path);
}

/// The CI perf gate end-to-end: `tfed diff` through the real binary,
/// exit 0 on a clean comparison and nonzero on an injected >threshold
/// samples/sec regression.
#[test]
fn diff_exit_codes_gate_regressions() {
    let path = fresh("gate");
    let p = path.to_str().unwrap();
    let ledger = Ledger::open(&path).unwrap();
    ledger
        .append(&[
            store::bench_record("train", &[("mlp/fp/blocked-4t/samples_per_sec".into(), 1000.0)]),
            store::bench_record("train", &[("mlp/fp/blocked-4t/samples_per_sec".into(), 500.0)]),
        ])
        .unwrap();

    let bin = env!("CARGO_BIN_EXE_tfed");
    let run = |a: &str, b: &str| {
        std::process::Command::new(bin)
            .args(["diff", a, b, "--ledger-out", p])
            .output()
            .expect("spawn tfed diff")
    };
    // 1000 → 500 samples/sec is a 50% drop: breach, nonzero exit
    let out = run("1", "2");
    assert!(!out.status.success(), "regression diff exited 0");
    assert!(String::from_utf8_lossy(&out.stderr).contains("perf gate"));
    // 500 → 1000 is a speedup: gate passes
    let out = run("2", "1");
    assert!(out.status.success(), "speedup diff exited nonzero: {:?}", out);
    assert!(String::from_utf8_lossy(&out.stdout).contains("perf gate: OK"));

    // history through the binary lists both bench records
    let out = std::process::Command::new(bin)
        .args(["history", "--ledger-out", p])
        .output()
        .expect("spawn tfed history");
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(stdout.matches("bench [train]").count(), 2);
    let _ = std::fs::remove_file(&path);
}
