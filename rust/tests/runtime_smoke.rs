//! Integration: load real artifacts, execute train/eval/quantize through
//! PJRT, cross-check quantize against the native Rust implementation.
//!
//! Requires `make artifacts`; tests no-op (with a note) if absent so
//! `cargo test` still works on a fresh checkout.

use tfed::model::init_params;
use tfed::quant;
use tfed::runtime::{manifest::default_artifacts_dir, Engine, Value};
use tfed::util::rng::Pcg;

fn engine() -> Option<Engine> {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load(default_artifacts_dir()).expect("engine"))
}

fn param_values(engine: &Engine, model: &str, seed: u64) -> Vec<Value> {
    let entry = engine.manifest.model(model).unwrap();
    let mut rng = Pcg::seeded(seed);
    let params = init_params(&entry.schema, &mut rng);
    params
        .tensors
        .iter()
        .map(|t| Value::f32(t.shape.clone(), t.data.clone()).unwrap())
        .collect()
}

#[test]
fn eval_artifact_runs_and_counts() {
    let Some(engine) = engine() else { return };
    let art = engine.manifest.eval_artifact("mlp").unwrap().clone();
    let (b, nb) = (art.batch, art.nb);
    let mut inputs = param_values(&engine, "mlp", 1);
    let mut rng = Pcg::seeded(2);
    let xs: Vec<f32> = (0..nb * b * 784).map(|_| rng.normal()).collect();
    let ys: Vec<i32> = (0..nb * b).map(|_| rng.below(10) as i32).collect();
    let mut ms = vec![1.0f32; nb * b];
    // mask out the last 10 samples
    for m in ms.iter_mut().rev().take(10) {
        *m = 0.0;
    }
    inputs.push(Value::f32(vec![nb, b, 784], xs).unwrap());
    inputs.push(Value::i32(vec![nb, b], ys).unwrap());
    inputs.push(Value::f32(vec![nb, b], ms).unwrap());
    let out = engine.execute(&art.name, &inputs).unwrap();
    assert_eq!(out.len(), 3);
    let loss_sum = out[0].scalar().unwrap();
    let correct = out[1].scalar().unwrap();
    let count = out[2].scalar().unwrap();
    assert_eq!(count, (nb * b - 10) as f32);
    assert!(loss_sum > 0.0 && loss_sum.is_finite());
    assert!(correct >= 0.0 && correct <= count);
    // random init on random data ~ chance accuracy
    let acc = correct / count;
    assert!(acc < 0.5, "acc={acc}");
}

#[test]
fn quantize_artifact_matches_native_quant() {
    let Some(engine) = engine() else { return };
    let art = engine.manifest.quantize_artifact("mlp").unwrap().clone();
    let entry = engine.manifest.model("mlp").unwrap().clone();
    let mut rng = Pcg::seeded(3);
    let params = init_params(&entry.schema, &mut rng);
    let qidx = entry.schema.quantized_indices();
    let inputs: Vec<Value> = qidx
        .iter()
        .map(|&i| {
            let t = &params.tensors[i];
            Value::f32(t.shape.clone(), t.data.clone()).unwrap()
        })
        .collect();
    let out = engine.execute(&art.name, &inputs).unwrap();
    assert_eq!(out.len(), 2 * qidx.len());
    let t_k = engine.manifest.t_k;
    for (k, &i) in qidx.iter().enumerate() {
        let hlo_it = out[k].as_f32().unwrap();
        let hlo_delta = out[qidx.len() + k].scalar().unwrap();
        let (native_it, native_delta) = quant::fttq_quantize(&params.tensors[i].data, t_k);
        assert!(
            (hlo_delta - native_delta).abs() < 1e-5,
            "layer {k}: delta {hlo_delta} vs {native_delta}"
        );
        let mut mismatches = 0usize;
        for (a, &b) in hlo_it.iter().zip(&native_it) {
            if (*a - b as f32).abs() > 0.5 {
                mismatches += 1;
            }
        }
        // identical math; allow a few boundary ties from float assoc.
        assert!(
            mismatches <= native_it.len() / 1000 + 1,
            "layer {k}: {mismatches}/{} mismatches",
            native_it.len()
        );
    }
}

#[test]
fn fp_train_epoch_reduces_loss_and_matches_io() {
    let Some(engine) = engine() else { return };
    let art = engine.manifest.train_artifact("mlp", "fp", 16).unwrap().clone();
    let (b, nb) = (art.batch, art.nb);
    let mut rng = Pcg::seeded(4);
    // learnable toy task: label = argmax of a fixed linear map
    let w_true: Vec<f32> = (0..784 * 10).map(|_| rng.normal()).collect();
    let n = nb * b;
    let xs: Vec<f32> = (0..n * 784).map(|_| rng.normal()).collect();
    let ys: Vec<i32> = (0..n)
        .map(|i| {
            let mut best = (f32::NEG_INFINITY, 0);
            for c in 0..10 {
                let mut s = 0f32;
                for k in 0..784 {
                    s += xs[i * 784 + k] * w_true[k * 10 + c];
                }
                if s > best.0 {
                    best = (s, c as i32);
                }
            }
            best.1
        })
        .collect();
    let ms = vec![1.0f32; n];

    let mut params = param_values(&engine, "mlp", 5);
    let mut losses = Vec::new();
    for _ in 0..6 {
        let mut inputs = params.clone();
        inputs.push(Value::f32(vec![nb, b, 784], xs.clone()).unwrap());
        inputs.push(Value::i32(vec![nb, b], ys.clone()).unwrap());
        inputs.push(Value::f32(vec![nb, b], ms.clone()).unwrap());
        inputs.push(Value::scalar_f32(0.3));
        let out = engine.execute(&art.name, &inputs).unwrap();
        assert_eq!(out.len(), art.outputs.len());
        losses.push(out.last().unwrap().scalar().unwrap());
        params = out[..6].to_vec();
    }
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "losses {losses:?}"
    );
}

#[test]
fn fttq_train_epoch_trains_wq() {
    let Some(engine) = engine() else { return };
    let art = engine.manifest.train_artifact("mlp", "fttq", 16).unwrap().clone();
    let (b, nb) = (art.batch, art.nb);
    let entry = engine.manifest.model("mlp").unwrap().clone();
    let nq = entry.num_quantized;
    let mut rng = Pcg::seeded(6);
    let n = nb * b;
    let xs: Vec<f32> = (0..n * 784).map(|_| rng.normal()).collect();
    let ys: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    let ms = vec![1.0f32; n];

    let mut inputs = param_values(&engine, "mlp", 7);
    let wq0 = engine.manifest.wq_init;
    inputs.push(Value::f32(vec![nq], vec![wq0; nq]).unwrap());
    // sgd: empty opt state
    inputs.push(Value::f32(vec![nb, b, 784], xs).unwrap());
    inputs.push(Value::i32(vec![nb, b], ys).unwrap());
    inputs.push(Value::f32(vec![nb, b], ms).unwrap());
    inputs.push(Value::scalar_f32(0.05));
    let out = engine.execute(&art.name, &inputs).unwrap();
    let loss = out.last().unwrap().scalar().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let wq = out[6].as_f32().unwrap();
    assert_eq!(wq.len(), nq);
    assert!(wq.iter().any(|&w| (w - wq0).abs() > 1e-6), "wq did not move: {wq:?}");
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(engine) = engine() else { return };
    let art = engine.manifest.eval_artifact("mlp").unwrap().clone();
    let inputs = vec![Value::scalar_f32(0.0); art.inputs.len()];
    let err = engine.execute(&art.name, &inputs).unwrap_err();
    assert!(format!("{err}").contains("expects shape"));
    let err = engine.execute(&art.name, &[]).unwrap_err();
    assert!(format!("{err}").contains("inputs"));
}
