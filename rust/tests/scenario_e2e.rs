//! End-to-end scenario-engine runs (native backend — no artifacts needed):
//! manifest parse → grid run → JSON bundle, CLI equivalence, Dirichlet
//! fleets, availability schedules, and the checked-in example manifests.

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::availability::{AvailabilityModel, Phase};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::run_experiment;
use tfed::coordinator::server::{FaultSpec, Orchestrator};
use tfed::eval::RunMetrics;
use tfed::scenario::{run_scenario, ScenarioManifest};
use tfed::util::json::Json;

/// Deterministic metrics fingerprint: the full JSON with wall-clock
/// timing zeroed (everything else — losses, accuracies, byte counts,
/// selections — must match byte-for-byte).
fn fingerprint(m: &RunMetrics) -> String {
    let mut m = m.clone();
    for r in &mut m.records {
        r.wall_secs = 0.0;
    }
    m.to_json().to_string()
}

#[test]
fn manifest_run_is_byte_identical_to_flag_driven_run() {
    // the manifest — a paper non-IID configuration (Nc = 2 label skew)
    // at test scale
    let manifest = ScenarioManifest::parse(
        r#"
[scenario]
name = "noniid_equivalence"
[experiment]
protocol = "tfedavg"
task = "mnist"
clients = 4
rounds = 3
local_epochs = 1
batch = 16
train_samples = 400
test_samples = 100
seed = 42
native = true
[fleet]
partition = "nc:2"
"#,
    )
    .unwrap();
    let scenario = run_scenario(&manifest).unwrap();
    assert_eq!(scenario.cells.len(), 1);

    // the equivalent flag-driven invocation:
    //   tfed run --protocol tfedavg --task mnist --clients 4 --nc 2
    //            --rounds 3 --epochs 1 --batch 16 --train-samples 400
    //            --test-samples 100 --seed 42 --native
    // (build_cfg starts from table2 and applies exactly these overrides)
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 42);
    cfg.n_clients = 4;
    cfg.nc = 2;
    cfg.rounds = 3;
    cfg.local_epochs = 1;
    cfg.batch = 16;
    cfg.train_samples = 400;
    cfg.test_samples = 100;
    cfg.native_backend = true;
    cfg.validate().unwrap();
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let flags = run_experiment(cfg, backend.as_ref()).unwrap();

    assert_eq!(fingerprint(&scenario.cells[0].metrics), fingerprint(&flags));
}

#[test]
fn manifest_parse_run_json_roundtrip() {
    let manifest = ScenarioManifest::parse(
        r#"
[scenario]
name = "roundtrip"
[experiment]
clients = 3
rounds = 2
local_epochs = 1
batch = 16
train_samples = 300
test_samples = 60
seed = 9
native = true
[fleet]
partition = "dirichlet:alpha=0.5"
[availability]
dropout = 0.2
[sweep]
seeds = [9, 10]
codecs = ["ternary", "stc:k=0.05"]
"#,
    )
    .unwrap();
    let results = run_scenario(&manifest).unwrap();
    assert_eq!(results.cells.len(), 4);

    // bundle → JSON text → parsed: identity on the deterministic fields
    let text = results.to_json().to_string_pretty();
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed.get("scenario").unwrap().as_str().unwrap(), "roundtrip");
    assert_eq!(parsed.get("grid_size").unwrap().as_usize().unwrap(), 4);
    let cells = parsed.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4);
    for (cell, run) in cells.iter().zip(&results.cells) {
        assert_eq!(cell.get("label").unwrap().as_str().unwrap(), run.label);
        assert_eq!(
            cell.get("seed").unwrap().as_usize().unwrap() as u64,
            run.seed
        );
        let best = cell
            .get("metrics")
            .unwrap()
            .get("best_acc")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!((best - run.metrics.best_acc() as f64).abs() < 1e-6);
    }
    // stc cells ride FedAvg (codec implies protocol), ternary cells T-FedAvg
    for run in &results.cells {
        let want = if run.codec == "ternary" { "T-FedAvg" } else { "FedAvg" };
        assert_eq!(run.protocol, want, "{}", run.label);
    }
}

#[test]
fn malformed_manifests_are_rejected() {
    for (src, why) in [
        ("", "empty"),
        ("just text", "not toml"),
        ("[scenario]\n", "missing name"),
        ("[scenario]\nname = \"x\"\n[fleeet]\npartition = \"iid\"\n", "table typo"),
        ("[scenario]\nname = \"x\"\n[fleet]\npartion = \"iid\"\n", "key typo"),
        (
            "[scenario]\nname = \"x\"\n[availability]\ndropout = 7.5\n",
            "probability out of range",
        ),
        (
            "[scenario]\nname = \"x\"\n[fleet]\npartition = \"dirichlet:alpha=-3\"\n",
            "negative alpha",
        ),
        (
            "[scenario]\nname = \"x\"\n[experiment]\nprotocol = \"tfedavg\"\n\
             [sweep]\ncodecs = [\"fp16\"]\n",
            "pinned protocol vs incompatible codec",
        ),
    ] {
        assert!(ScenarioManifest::parse(src).is_err(), "accepted {why}: {src:?}");
    }
}

#[test]
fn dirichlet_fleet_runs_end_to_end() {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 21);
    cfg.n_clients = 4;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 400;
    cfg.test_samples = 100;
    cfg.batch = 16;
    cfg.dirichlet_alpha = 0.3;
    cfg.native_backend = true;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let m = run_experiment(cfg, backend.as_ref()).unwrap();
    assert_eq!(m.records.len(), 2);
    assert!(m.records.iter().all(|r| r.train_loss.is_finite()));
}

#[test]
fn orchestrator_rejects_invalid_fault_probabilities() {
    // regression for the unvalidated-FaultSpec bug: NaN / out-of-range
    // dropout used to flow silently into apply_dropout
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 1);
    cfg.n_clients = 2;
    cfg.rounds = 1;
    cfg.train_samples = 200;
    cfg.test_samples = 50;
    cfg.batch = 16;
    cfg.native_backend = true;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    for p in [-0.1, 1.5, f64::NAN] {
        let r = Orchestrator::with_faults(
            cfg.clone(),
            backend.as_ref(),
            FaultSpec { client_dropout: p },
        );
        assert!(r.is_err(), "dropout={p} was accepted");
        assert!(FaultSpec::new(p).is_err(), "FaultSpec::new({p}) was accepted");
    }
    // valid boundary still works
    Orchestrator::with_faults(cfg, backend.as_ref(), FaultSpec { client_dropout: 0.0 })
        .unwrap();
}

#[test]
fn phased_dropout_and_stragglers_drive_rounds() {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 33);
    cfg.n_clients = 4;
    cfg.rounds = 4;
    cfg.local_epochs = 1;
    cfg.train_samples = 400;
    cfg.test_samples = 100;
    cfg.batch = 16;
    cfg.native_backend = true;
    let availability = AvailabilityModel::new(
        0.0,
        vec![Phase { from_round: 3, dropout: 0.9 }],
        0.5,
        1, // 1 ms straggler delay: exercises the path without slowing CI
    )
    .unwrap();
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let mut orch =
        Orchestrator::with_availability(cfg, backend.as_ref(), availability).unwrap();
    orch.run().unwrap();
    let recs = &orch.metrics.records;
    assert_eq!(recs.len(), 4);
    // phase off: full participation in rounds 1-2
    assert_eq!(recs[0].selected.len(), 4);
    assert_eq!(recs[1].selected.len(), 4);
    // phase on: heavy dropout must have bitten at least once in rounds 3-4
    assert!(
        recs[2].selected.len() < 4 || recs[3].selected.len() < 4,
        "dropout phase never engaged: {:?}",
        recs.iter().map(|r| r.selected.len()).collect::<Vec<_>>()
    );
    assert!(orch.global().is_finite());
}

#[test]
fn default_availability_is_bit_identical_to_seed_path() {
    // an explicitly-trivial availability model must not perturb the RNG
    // stream: identical selections and results to the default constructor
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 55);
    cfg.n_clients = 4;
    cfg.participation = 0.5;
    cfg.rounds = 3;
    cfg.local_epochs = 1;
    cfg.train_samples = 400;
    cfg.test_samples = 100;
    cfg.batch = 16;
    cfg.native_backend = true;
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let baseline = run_experiment(cfg.clone(), backend.as_ref()).unwrap();
    let mut orch = Orchestrator::with_availability(
        cfg,
        backend.as_ref(),
        AvailabilityModel::always_on(),
    )
    .unwrap();
    orch.run().unwrap();
    assert_eq!(fingerprint(&baseline), fingerprint(&orch.metrics));
}

#[test]
fn checked_in_example_manifests_are_valid() {
    // cargo test runs from rust/; the manifests live beside the examples
    let smoke = ScenarioManifest::load("../examples/scenarios/smoke.toml").unwrap();
    assert!(smoke.base.native_backend, "CI smoke must not need artifacts");
    let grid = smoke.grid().unwrap();
    assert!(!grid.is_empty());
    for cell in &grid {
        assert!(cell.cfg.rounds <= 2, "smoke manifest must stay <= 2 rounds");
    }

    let paper = ScenarioManifest::load("../examples/scenarios/paper_noniid.toml").unwrap();
    let grid = paper.grid().unwrap();
    // the Fig. 8/9 axis: IID vs label-skew partitions, multiple seeds
    assert!(grid.len() >= 6, "paper grid has {} cells", grid.len());
    assert!(grid.iter().any(|c| c.partition.starts_with("nc:")));
    assert!(grid.iter().any(|c| c.partition.starts_with("dirichlet:")));
}

#[test]
fn smoke_manifest_runs_end_to_end() {
    // the exact artifact CI smoke-runs via `tfed run`; keep it fast here
    // too (≤ 2 rounds by construction, asserted above)
    let manifest = ScenarioManifest::load("../examples/scenarios/smoke.toml").unwrap();
    let results = run_scenario(&manifest).unwrap();
    assert!(!results.cells.is_empty());
    for c in &results.cells {
        assert!(c.metrics.records.iter().all(|r| r.train_loss.is_finite()));
    }
    Json::parse(&results.to_json().to_string_pretty()).unwrap();
}
