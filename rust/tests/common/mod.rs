//! Helpers shared across the e2e integration suites (`mod common;`).
//!
//! Each integration test file is its own crate, so anything needed by
//! more than one suite lives here: the deterministic run fingerprint and
//! the in-thread TCP federation driver. Suites that only use a subset
//! would otherwise warn, hence the file-level `dead_code` allow.
#![allow(dead_code)]

use tfed::config::ExperimentConfig;
use tfed::coordinator::availability::AvailabilityModel;
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::{materialize_data, Orchestrator};
use tfed::coordinator::{AdversaryModel, ClientAdversary, ClientRuntime};
use tfed::eval::RunMetrics;
use tfed::model::ParamSet;
use tfed::transport::{TcpBinding, TcpClient};

/// Deterministic metrics fingerprint: the full metrics JSON with the
/// wall clock zeroed (losses, accuracies, selections, byte counts, and
/// the virtual clock all remain — they must reproduce).
pub fn fingerprint(m: &RunMetrics) -> String {
    let mut m = m.clone();
    for r in &mut m.records {
        r.wall_secs = 0.0;
    }
    m.to_json().to_string()
}

/// Drive one experiment over real TCP sockets with in-thread clients;
/// returns the run metrics and the final global parameters.
///
/// Each client derives its Byzantine role (if any) from the
/// wire-delivered config, exactly like the `tfed client` subcommand, so
/// adversarial suites can reuse this driver unchanged.
pub fn run_over_tcp(cfg: &ExperimentConfig) -> (RunMetrics, ParamSet) {
    let backend = make_backend(None, "mlp", cfg.batch, true).unwrap();
    let binding = TcpBinding::bind("127.0.0.1:0").unwrap();
    let addr = binding.local_addr().unwrap();
    let (shards, _test) = materialize_data(cfg, backend.schema().input_dim).unwrap();
    std::thread::scope(|s| {
        for (cid, shard) in shards.into_iter().enumerate() {
            let backend = backend.as_ref();
            let want_cfg = cfg.clone();
            s.spawn(move || {
                let (mut client, got_cfg) =
                    TcpClient::connect(&addr.to_string(), cid as u32).unwrap();
                // the wire-delivered config is exactly the server's
                assert_eq!(got_cfg, want_cfg);
                let cast = AdversaryModel::new(got_cfg.adversary).unwrap();
                let runtime = ClientRuntime {
                    client_id: cid as u32,
                    backend,
                    shard,
                    local_epochs: got_cfg.local_epochs,
                    lr: got_cfg.lr,
                    codec: got_cfg.codec,
                    adversary: ClientAdversary::from_model(cast),
                };
                let rounds = client.serve(&runtime).unwrap();
                assert_eq!(rounds as usize, got_cfg.rounds);
            });
        }
        let transport = binding.accept_clients(cfg.n_clients, cfg).unwrap();
        let mut orch = Orchestrator::with_transport(
            cfg.clone(),
            backend.as_ref(),
            AvailabilityModel::always_on(),
            Box::new(transport),
        )
        .unwrap();
        // shut the clients down before asserting, so a failed run reports
        // the driver's error rather than client-side panics
        let run_result = orch.run();
        orch.shutdown_transport().unwrap();
        run_result.unwrap();
        (orch.metrics.clone(), orch.global().clone())
    })
}
