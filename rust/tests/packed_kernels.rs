//! Packed-ternary kernel tier property suite (DESIGN.md §15).
//!
//! The packed tier computes on the 2-bit ternary cells directly, so its
//! float-op order legitimately differs from the fp32 contract — it gets
//! its own determinism oracle instead of joining the seed-bit-identity
//! chain. This suite asserts, at the integration level:
//!
//! * packed fast path ≡ naive packed oracle, bit for bit, at every
//!   thread count, over random shapes *and* the real mlp-large / cnn
//!   layer shapes (forward + grad_input);
//! * |packed − fp32| stays inside a principled accumulation-error bound
//!   against an f64 reference (the tiers compute the same math, just in
//!   a different order);
//! * graph-level training under the packed tier is thread-count
//!   invariant, and a full federated protocol run on the packed tier is
//!   deterministic across reruns (everything but wall time, bitwise).

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::NativeBackend;
use tfed::coordinator::server::run_experiment;
use tfed::model::{init_params, registry};
use tfed::native::kernels::{
    gemm_bias, packed_gemm_bias, packed_gemm_bias_naive, packed_grad_input,
    packed_grad_input_naive,
};
use tfed::native::{KernelPolicy, LayerGraph, Mode, PackedWeights};
use tfed::util::rng::Pcg;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn trits(rng: &mut Pcg, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.below(3) as i8) - 1).collect()
}

fn randn(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

/// The quantized layers' lowered GEMM shapes: mlp-large's three dense
/// matrices and cnn's two im2col-lowered convs plus its dense head.
const REAL_SHAPES: &[(usize, usize)] =
    &[(784, 256), (256, 128), (128, 10), (27, 8), (72, 16), (256, 10)];

#[test]
fn packed_forward_matches_its_oracle_on_real_and_random_shapes() {
    let mut rng = Pcg::seeded(401);
    let random_shapes = [(5usize, 3usize), (33, 65), (130, 66), (1, 17)];
    for &(k, o) in REAL_SHAPES.iter().chain(&random_shapes) {
        let n = 9usize;
        let it = trits(&mut rng, k * o);
        let pw = PackedWeights::from_pattern(&it, k, o);
        let x = randn(&mut rng, n * k);
        let b = randn(&mut rng, o);
        // symmetric (fttq) and asymmetric (ttq) scale pairs hit both
        // accumulator layouts of the contract
        for (ps, ns) in [(0.05f32, 0.05f32), (0.04, 0.07)] {
            let mut want = vec![0f32; n * o];
            packed_gemm_bias_naive(&x, &pw, &b, ps, ns, &mut want, n);
            for threads in [1usize, 2, 3, 8] {
                let policy = KernelPolicy::packed(threads);
                let mut got = vec![0f32; n * o];
                packed_gemm_bias(&x, &pw, &b, ps, ns, &mut got, n, &policy);
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "forward {k}x{o} scales ({ps},{ns}) threads {threads}"
                );
            }
        }
    }
}

#[test]
fn packed_grad_input_matches_its_oracle_on_real_and_random_shapes() {
    let mut rng = Pcg::seeded(402);
    let random_shapes = [(5usize, 3usize), (33, 65), (130, 66)];
    for &(k, o) in REAL_SHAPES.iter().chain(&random_shapes) {
        let n = 7usize;
        let it = trits(&mut rng, k * o);
        let pw = PackedWeights::from_pattern(&it, k, o);
        let g = randn(&mut rng, n * o);
        for (ps, ns) in [(0.05f32, 0.05f32), (0.04, 0.07)] {
            let mut want = vec![0f32; n * k];
            packed_grad_input_naive(&g, &pw, ps, ns, &mut want, n);
            for threads in [1usize, 2, 8] {
                let policy = KernelPolicy::packed(threads);
                let mut got = vec![0f32; n * k];
                packed_grad_input(&g, &pw, ps, ns, &mut got, n, &policy);
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "grad_input {k}x{o} scales ({ps},{ns}) threads {threads}"
                );
            }
        }
    }
}

#[test]
fn packed_tracks_fp32_inside_an_accumulation_error_bound() {
    // both tiers compute b + Σ x·(±scale on the pattern support); an f64
    // reference bounds each of them by the standard sequential-sum error
    // k·ε·Σ|terms|, so |packed − fp32| is bounded by twice that
    let mut rng = Pcg::seeded(403);
    for &(k, o) in REAL_SHAPES {
        let n = 5usize;
        let it = trits(&mut rng, k * o);
        let pw = PackedWeights::from_pattern(&it, k, o);
        let x = randn(&mut rng, n * k);
        let b = randn(&mut rng, o);
        let wq = 0.05f32;
        let w_eff: Vec<f32> = it.iter().map(|&t| t as f32 * wq).collect();

        let mut fp = vec![0f32; n * o];
        gemm_bias(&x, &w_eff, &b, &mut fp, n, k, o, &KernelPolicy::threaded(2));
        let mut packed = vec![0f32; n * o];
        packed_gemm_bias(&x, &pw, &b, wq, wq, &mut packed, n, &KernelPolicy::packed(2));

        for i in 0..n {
            for oo in 0..o {
                let mut acc = b[oo] as f64;
                let mut mag = (b[oo] as f64).abs();
                for kk in 0..k {
                    let term = x[i * k + kk] as f64 * w_eff[kk * o + oo] as f64;
                    acc += term;
                    mag += term.abs();
                }
                let bound = 2.0 * (k as f64) * f64::from(f32::EPSILON) * mag + 1e-7;
                let pv = packed[i * o + oo] as f64;
                let fv = fp[i * o + oo] as f64;
                assert!(
                    (pv - acc).abs() <= bound,
                    "{k}x{o} [{i},{oo}]: packed {pv} vs f64 {acc} (bound {bound})"
                );
                assert!(
                    (pv - fv).abs() <= 2.0 * bound,
                    "{k}x{o} [{i},{oo}]: packed {pv} vs fp32 {fv} (bound {})",
                    2.0 * bound
                );
            }
        }
    }
}

#[test]
fn packed_training_is_thread_count_invariant_at_the_graph_level() {
    for (model, mode) in [("mlp-large", Mode::Fttq), ("cnn", Mode::Ttq)] {
        let def = registry::model_def(model).unwrap();
        let dim = def.schema.input_dim;
        let classes = def.schema.num_classes;
        let mut data_rng = Pcg::seeded(404);
        let x: Vec<f32> = (0..32 * dim).map(|_| data_rng.normal().max(0.0)).collect();
        let y: Vec<u32> = (0..32).map(|_| data_rng.below(classes as u32)).collect();
        let mut want: Option<(Vec<u32>, Vec<u32>)> = None;
        for policy in [
            KernelPolicy::packed_reference(),
            KernelPolicy::packed(1),
            KernelPolicy::packed(4),
        ] {
            let graph = LayerGraph::from_def(&def, mode, 0.05, policy).unwrap();
            let mut params = init_params(&def.schema, &mut Pcg::seeded(9));
            let mut factors = vec![0.05f32; graph.factors_len()];
            for _ in 0..2 {
                graph.train_batch(&mut params, &mut factors, &x, &y, 32, 0.05).unwrap();
            }
            let got = (
                params
                    .tensors
                    .iter()
                    .flat_map(|t| t.data.iter().map(|v| v.to_bits()))
                    .collect::<Vec<_>>(),
                bits(&factors),
            );
            match &want {
                None => want = Some(got),
                Some(w) => assert_eq!(w, &got, "{model} {policy:?} diverged"),
            }
        }
    }
}

#[test]
fn packed_tier_protocol_run_is_deterministic_across_reruns() {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 17);
    cfg.n_clients = 3;
    cfg.rounds = 2;
    cfg.local_epochs = 1;
    cfg.train_samples = 300;
    cfg.test_samples = 100;
    cfg.batch = 16;
    cfg.native_backend = true;
    let run = || {
        let mut backend = NativeBackend::for_model("mlp", cfg.batch).unwrap();
        backend.set_policy(KernelPolicy::packed(2));
        run_experiment(cfg.clone(), &backend).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.records.len(), 2);
    for (ra, rb) in a.records.iter().zip(&b.records) {
        // everything but the wall clock, bitwise
        assert_eq!(ra.round, rb.round);
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits());
        assert_eq!(ra.test_acc.to_bits(), rb.test_acc.to_bits());
        assert_eq!(ra.test_loss.to_bits(), rb.test_loss.to_bits());
        assert_eq!(ra.up_bytes, rb.up_bytes);
        assert_eq!(ra.down_bytes, rb.down_bytes);
        assert_eq!(ra.up_frames, rb.up_frames);
        assert_eq!(ra.down_frames, rb.down_frames);
        assert_eq!(bits(&ra.factors), bits(&rb.factors));
    }
    assert!(a.final_acc().is_finite());
}
