//! Regenerates the paper's FIGURES (6-13) as printed series + CSVs in
//! bench_out/. Same shape-not-absolute philosophy as paper_tables.rs.
//!
//!     cargo bench --bench paper_figures                # all figures
//!     cargo bench --bench paper_figures -- --fig7      # one figure
//!     cargo bench --bench paper_figures -- --ablation  # design ablations

#[path = "common.rs"]
mod common;

use common::*;
use tfed::config::{Protocol, Task};
use tfed::coordinator::server::Orchestrator;
use tfed::data::partition::{partition, PartitionSpec};
use tfed::data::synth::SynthSpec;
use tfed::quant;
use tfed::util::logging;

fn main() {
    logging::set_level(logging::Level::Warn);
    let sections = selected_sections();
    let engine = engine();

    if section_enabled(&sections, "fig6") {
        fig6(&engine);
    }
    if section_enabled(&sections, "fig7") {
        fig7(&engine);
    }
    if section_enabled(&sections, "fig8") {
        fig8(&engine);
    }
    if section_enabled(&sections, "fig9") {
        fig9();
    }
    if section_enabled(&sections, "fig10") {
        fig10(&engine);
    }
    if section_enabled(&sections, "fig11") {
        fig11(&engine);
    }
    if section_enabled(&sections, "fig12") {
        fig12(&engine);
    }
    if section_enabled(&sections, "ablation") {
        ablation(&engine);
    }
}

/// Fig. 6: convergence curves of the four methods (mnist-like task).
fn fig6(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Fig. 6: convergence over rounds (mnist-like) ===");
    let mut rows: Vec<String> = Vec::new();
    let mut curves = Vec::new();
    for protocol in [Protocol::Baseline, Protocol::FedAvg, Protocol::Ttq, Protocol::TFedAvg] {
        let mut cfg = bench_cfg(protocol, Task::MnistLike, 21);
        let backend = backend_for(engine, &mut cfg);
        let m = run(cfg, backend.as_ref());
        curves.push((protocol.name().to_string(), m.acc_series()));
    }
    let rounds = curves[0].1.len();
    println!("{:>6} {:>10} {:>10} {:>10} {:>10}", "round", "Baseline", "FedAvg", "TTQ", "T-FedAvg");
    for i in 0..rounds {
        let r = curves[0].1[i].0;
        let vals: Vec<f32> = curves.iter().map(|(_, c)| c.get(i).map(|x| x.1).unwrap_or(f32::NAN)).collect();
        println!(
            "{:>6} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            r, vals[0], vals[1], vals[2], vals[3]
        );
        rows.push(format!("{},{:.4},{:.4},{:.4},{:.4}", r, vals[0], vals[1], vals[2], vals[3]));
    }
    write_csv("fig6.csv", "round,baseline,fedavg,ttq,tfedavg", &rows);
    println!("paper shape: all four converge to a similar plateau; quantized");
    println!("methods track the full-precision ones.");
}

/// Fig. 7: accuracy vs local batch size, FedAvg vs T-FedAvg.
fn fig7(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Fig. 7: accuracy vs local batch size (mnist-like) ===");
    let batches = match engine {
        Some(e) => e.manifest.train_batches("mlp"),
        None => vec![16, 32, 64, 128],
    };
    println!("{:>6} {:>10} {:>10}", "B", "FedAvg", "T-FedAvg");
    let mut rows = Vec::new();
    for &b in &batches {
        let mut cells = Vec::new();
        for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
            let mut cfg = bench_cfg(protocol, Task::MnistLike, 13);
            cfg.batch = b;
            let backend = backend_for(engine, &mut cfg);
            let m = run(cfg, backend.as_ref());
            cells.push(m.best_acc());
        }
        println!("{:>6} {:>10.4} {:>10.4}", b, cells[0], cells[1]);
        rows.push(format!("{},{:.4},{:.4}", b, cells[0], cells[1]));
    }
    write_csv("fig7.csv", "batch,fedavg,tfedavg", &rows);
    println!("paper shape: T-FedAvg >= FedAvg at small B (more iterations reduce");
    println!("quantization error); the gap narrows/reverses at large B.");
}

/// Fig. 8: accuracy vs Nc (classes per client), full participation.
fn fig8(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Fig. 8: accuracy vs Nc (mnist-like, non-IID) ===");
    println!("{:>6} {:>10} {:>10}", "Nc", "FedAvg", "T-FedAvg");
    let mut rows = Vec::new();
    for nc in [2usize, 3, 5, 8, 10] {
        let mut cells = Vec::new();
        for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
            let mut cfg = bench_cfg(protocol, Task::MnistLike, 17);
            cfg.nc = nc;
            let backend = backend_for(engine, &mut cfg);
            let m = run(cfg, backend.as_ref());
            cells.push(m.best_acc());
        }
        println!("{:>6} {:>10.4} {:>10.4}", nc, cells[0], cells[1]);
        rows.push(format!("{},{:.4},{:.4}", nc, cells[0], cells[1]));
    }
    write_csv("fig8.csv", "nc,fedavg,tfedavg", &rows);
    println!("paper shape: monotone degradation as Nc shrinks; the two protocols");
    println!("stay within noise of each other at every Nc.");
}

/// Fig. 9: per-client label distributions for Nc = 2, 5, 10.
fn fig9() {
    println!("\n=== Fig. 9: client label histograms by Nc (first 3 clients) ===");
    let (train, _) = SynthSpec::mnist_like(2_000, 100, 9).generate();
    let mut rows = Vec::new();
    for nc in [2usize, 5, 10] {
        let p = partition(&train, &PartitionSpec::non_iid(10, nc, 9)).unwrap();
        println!("Nc = {nc}:");
        for shard in p.shards.iter().take(3) {
            let h = shard.class_histogram(&train);
            println!("  client {}: {:?}", shard.client_id, h);
            rows.push(format!(
                "{},{},{}",
                nc,
                shard.client_id,
                h.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
            ));
        }
        let present: Vec<usize> = p
            .shards
            .iter()
            .map(|s| s.class_histogram(&train).iter().filter(|&&c| c > 0).count())
            .collect();
        println!("  classes-per-client across all 10 clients: {present:?}");
    }
    write_csv("fig9.csv", "nc,client,c0,c1,c2,c3,c4,c5,c6,c7,c8,c9", &rows);
    println!("paper shape: Nc=2 -> 2 disjoint label blocks per client; Nc=5 ->");
    println!("partial overlap; Nc=10 -> uniform coverage.");
}

/// Fig. 10: accuracy vs participation ratio, IID and non-IID.
fn fig10(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Fig. 10: T-FedAvg accuracy vs participation ratio (mnist-like) ===");
    println!("{:>8} {:>10} {:>12}", "lambda", "IID", "non-IID(5)");
    let mut rows = Vec::new();
    for lambda in [0.1, 0.3, 0.5, 0.7] {
        let mut cells = Vec::new();
        for nc in [10usize, 5] {
            let mut cfg = bench_cfg(Protocol::TFedAvg, Task::MnistLike, 19);
            cfg.n_clients = 30; // scaled from the paper's 100 (runtime)
            cfg.participation = lambda;
            cfg.nc = nc;
            let backend = backend_for(engine, &mut cfg);
            let m = run(cfg, backend.as_ref());
            cells.push(m.best_acc());
        }
        println!("{:>8.1} {:>10.4} {:>12.4}", lambda, cells[0], cells[1]);
        rows.push(format!("{},{:.4},{:.4}", lambda, cells[0], cells[1]));
    }
    write_csv("fig10.csv", "lambda,iid,non_iid_nc5", &rows);
    println!("paper shape: robust to lambda on IID; lower lambda hurts more on");
    println!("non-IID (representativeness of the selected cohort).");
}

/// Fig. 11: accuracy vs unbalancedness beta.
fn fig11(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Fig. 11: accuracy vs unbalancedness beta (mnist-like) ===");
    println!("{:>6} {:>10} {:>10}", "beta", "FedAvg", "T-FedAvg");
    let mut rows = Vec::new();
    for beta in [0.1, 0.25, 0.5, 0.75, 1.0] {
        let mut cells = Vec::new();
        for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
            let mut cfg = bench_cfg(protocol, Task::MnistLike, 23);
            cfg.n_clients = 20;
            cfg.participation = 0.3;
            cfg.beta = beta;
            let backend = backend_for(engine, &mut cfg);
            let m = run(cfg, backend.as_ref());
            cells.push(m.best_acc());
        }
        println!("{:>6.2} {:>10.4} {:>10.4}", beta, cells[0], cells[1]);
        rows.push(format!("{},{:.4},{:.4}", beta, cells[0], cells[1]));
    }
    write_csv("fig11.csv", "beta,fedavg,tfedavg", &rows);
    println!("paper shape: flat in beta — unbalanced shard sizes alone do not");
    println!("hurt either protocol.");
}

/// Figs. 12-13 (appendix): TTQ two-factor convergence traces.
fn fig12(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Figs. 12-13: TTQ w_p / w_n convergence (centralized mlp) ===");
    let mut cfg = bench_cfg(Protocol::Ttq, Task::MnistLike, 29);
    cfg.eval_every = cfg.rounds; // factors are what we're after
    let rounds = cfg.rounds;
    let backend = backend_for(engine, &mut cfg);
    let mut orch = Orchestrator::new(cfg, backend.as_ref()).expect("orch");
    println!("{:>6} {:>24} {:>24}", "round", "wp(l1,l2,l3)", "wn(l1,l2,l3)");
    let mut rows = Vec::new();
    let mut gaps: Vec<f64> = Vec::new();
    for r in 1..=rounds {
        let rec = orch.round(r).expect("round");
        let f = &rec.factors;
        let nq = f.len() / 2;
        let wp = &f[..nq];
        let wn = &f[nq..];
        println!(
            "{:>6} {:>24} {:>24}",
            r,
            format!("{:.3},{:.3},{:.3}", wp[0], wp[1], wp[2]),
            format!("{:.3},{:.3},{:.3}", wn[0], wn[1], wn[2])
        );
        rows.push(format!(
            "{},{}",
            r,
            f.iter().map(|v| format!("{v:.5}")).collect::<Vec<_>>().join(",")
        ));
        let gap: f64 = wp
            .iter()
            .zip(wn)
            .map(|(p, n)| (p.abs() - n.abs()).abs() as f64)
            .sum::<f64>()
            / nq as f64;
        gaps.push(gap);
    }
    write_csv("fig12.csv", "round,wp1,wp2,wp3,wn1,wn2,wn3", &rows);
    println!(
        "mean |wp - wn| gap: first rounds {:.4} -> last rounds {:.4}",
        gaps.iter().take(3).sum::<f64>() / 3.0,
        gaps.iter().rev().take(3).sum::<f64>() / 3.0,
    );
    println!("paper shape (Prop 4.1): the two factors move with the same trend;");
    println!("their absolute values converge toward each other.");
}

/// Design ablations called out in DESIGN.md §5.
fn ablation(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Ablation: server re-quantization threshold Delta ===");
    // train one T-FedAvg model, then re-quantize the final global at
    // several fixed thresholds and compare 2-bit inference accuracy
    let mut cfg = bench_cfg(Protocol::TFedAvg, Task::MnistLike, 37);
    let backend = backend_for(engine, &mut cfg);
    let mut orch = Orchestrator::new(cfg, backend.as_ref()).expect("orch");
    orch.run().expect("run");
    let global = orch.global().clone();
    let schema = backend.schema().clone();
    let qidx = schema.quantized_indices();
    let (test_data, _) = {
        let mut c2 = bench_cfg(Protocol::TFedAvg, Task::MnistLike, 37);
        c2.native_backend = false;
        let spec = SynthSpec::mnist_like(c2.train_samples, c2.test_samples, c2.seed);
        let (_, test) = spec.generate();
        (tfed::coordinator::client::ShardData::whole(&test), ())
    };
    println!("{:>8} {:>10} {:>12}", "Delta", "acc", "sparsity");
    let mut rows = Vec::new();
    for delta in [0.01f32, 0.05, 0.1, 0.2, 0.4] {
        let mut model = global.clone();
        let mut sparsity_acc = 0.0;
        for &i in &qidx {
            let (it, wq) = {
                let s = quant::scale(&global.tensors[i].data);
                let it = quant::ternarize(&s, delta);
                let wq = quant::optimal_wq_symmetric(&global.tensors[i].data, &it);
                (it, wq)
            };
            sparsity_acc += quant::sparsity(&it) / qidx.len() as f64;
            for (dst, &sgn) in model.tensors[i].data.iter_mut().zip(&it) {
                *dst = wq * sgn as f32;
            }
        }
        let (_, acc) = backend.evaluate(&model, &test_data).expect("eval");
        println!("{:>8.2} {:>10.4} {:>12.3}", delta, acc, sparsity_acc);
        rows.push(format!("{},{:.4},{:.4}", delta, acc, sparsity_acc));
    }
    write_csv("ablation_delta.csv", "delta,acc,sparsity", &rows);
    println!("expected: accuracy flat for small Delta (paper default 0.05),");
    println!("degrading once sparsity grows aggressive.");

    println!("\n=== Ablation: bare-sign vs eq.20-scaled ternary inference ===");
    let bare = orch.broadcast_model();
    let scaled = orch.ternary_inference_model();
    let (_, acc_bare) = backend.evaluate(&bare, &test_data).expect("eval");
    let (_, acc_scaled) = backend.evaluate(&scaled, &test_data).expect("eval");
    let (_, acc_dense) = backend.evaluate(&global, &test_data).expect("eval");
    println!("bare {{-1,0,+1}}: {acc_bare:.4}   eq.20-scaled: {acc_scaled:.4}   dense: {acc_dense:.4}");
    println!("(the per-layer scale is what makes the 2-bit model usable — see");
    println!("DESIGN.md; client training is invariant to it.)");
}
