//! Regenerates the paper's TABLES (I, II, III, IV) on the synthetic
//! substrate, plus the codec-comparison table the compression subsystem
//! adds on top. Absolute numbers differ from the paper (different data,
//! reduced scale — see DESIGN.md §3/§5); the *shape* — who wins, by what
//! factor — is the reproduction target. Run via:
//!
//!     cargo bench --bench paper_tables            # all tables
//!     cargo bench --bench paper_tables -- --table4
//!     cargo bench --bench paper_tables -- --compression
//!     cargo bench --bench paper_tables -- --sim
//!     cargo bench --bench paper_tables -- --train
//!     TFED_BENCH_SCALE=full cargo bench --bench paper_tables
//!
//! CSV output lands in bench_out/; the compression section additionally
//! emits machine-readable BENCH_compression.json at the repo root so the
//! per-codec bytes/round trajectory is tracked PR over PR, the sim
//! section emits BENCH_sim.json (per-codec rounds-per-virtual-hour and
//! simulated time-to-accuracy over a 100k-registered-client fleet), and
//! the train section emits BENCH_train.json (native layer-graph training
//! throughput per model x mode x kernel/thread config, naive baseline
//! included, bit-identity asserted). With TFED_LEDGER=<path> set, the
//! compression/sim/train sections additionally append their headline
//! numbers as bench records to that run ledger, so `tfed history` /
//! `tfed diff` can gate perf regressions across bench runs.

#[path = "common.rs"]
mod common;

use common::*;
use tfed::compress::CodecSpec;
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::util::logging;

fn main() {
    logging::set_level(logging::Level::Warn);
    let sections = selected_sections();
    let engine = engine();

    if section_enabled(&sections, "table1") {
        table1();
    }
    if section_enabled(&sections, "table2") {
        table2(&engine);
    }
    if section_enabled(&sections, "table3") {
        table3(&engine);
    }
    if section_enabled(&sections, "table4") {
        table4(&engine);
    }
    if section_enabled(&sections, "compression") {
        compression(&engine);
    }
    if section_enabled(&sections, "sim") {
        sim();
    }
    if section_enabled(&sections, "train") {
        train();
    }
}

/// Table I: models and hyperparameters (ours vs paper).
fn table1() {
    println!("\n=== Table I: models and hyperparameters ===");
    println!("{:<22} {:<18} {:<18}", "", "MLP (mnist-like)", "ResNetLite (cifar-like)");
    println!("{:<22} {:<18} {:<18}", "paper model", "MLP 784-30-20-10", "ResNet18* (reduced)");
    println!("{:<22} {:<18} {:<18}", "optimizer", "SGD", "Adam");
    println!("{:<22} {:<18} {:<18}", "paper lr", "0.0001", "0.008");
    println!("{:<22} {:<18} {:<18}", "ours lr (synthetic)", "0.05-0.2", "0.002");
    println!("{:<22} {:<18} {:<18}", "params (paper)", "24330", "607050");
    println!("{:<22} {:<18} {:<18}", "params (ours)", "24380", "52970");
}

/// Table II: IID accuracy x {Baseline, FedAvg, TTQ, T-FedAvg} x 2 tasks.
fn table2(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Table II: test accuracy + weight width, IID data ===");
    println!(
        "{:<10} | {:>10} {:>7} | {:>10} {:>7}",
        "method", "mnist-like", "width", "cifar-like", "width"
    );
    let protocols = [Protocol::Baseline, Protocol::FedAvg, Protocol::Ttq, Protocol::TFedAvg];
    let mut rows = Vec::new();
    for protocol in protocols {
        let mut cells = Vec::new();
        for task in [Task::MnistLike, Task::CifarLike] {
            // offline, the cifar column runs the native registry `cnn`
            let mut cfg = bench_cfg(protocol, task, 42);
            let backend = backend_for(engine, &mut cfg);
            let m = run(cfg, backend.as_ref());
            cells.push(m.best_acc());
        }
        println!(
            "{:<10} | {:>9.2}% {:>6}b | {:>9.2}% {:>6}b",
            protocol.name(),
            cells[0] * 100.0,
            protocol.weight_bits(),
            cells[1] * 100.0,
            protocol.weight_bits()
        );
        rows.push(format!(
            "{},{:.4},{:.4},{}",
            protocol.name(),
            cells[0],
            cells[1],
            protocol.weight_bits()
        ));
    }
    write_csv("table2.csv", "method,mnist_acc,cifar_acc,width_bits", &rows);
    println!("paper shape: all four methods within ~1% of each other per task;");
    println!("2-bit methods match (or slightly beat) their 32-bit counterparts.");
}

/// Table III: non-IID accuracy (Nc = 2, 5) for FedAvg and T-FedAvg.
fn table3(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Table III: test accuracy on non-IID data ===");
    println!(
        "{:<10} | {:>9} {:>9} | {:>9} {:>9}",
        "method", "mnist Nc2", "mnist Nc5", "cifar Nc2", "cifar Nc5"
    );
    let mut rows = Vec::new();
    for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
        let mut cells = Vec::new();
        for task in [Task::MnistLike, Task::CifarLike] {
            for nc in [2usize, 5] {
                let mut cfg = bench_cfg(protocol, task, 7);
                cfg.nc = nc;
                let backend = backend_for(engine, &mut cfg);
                let m = run(cfg, backend.as_ref());
                cells.push(m.best_acc());
            }
        }
        println!(
            "{:<10} | {:>8.2}% {:>8.2}% | {:>8.2}% {:>8.2}%",
            protocol.name(),
            cells[0] * 100.0,
            cells[1] * 100.0,
            cells[2] * 100.0,
            cells[3] * 100.0
        );
        rows.push(format!(
            "{},{:.4},{:.4},{:.4},{:.4}",
            protocol.name(),
            cells[0],
            cells[1],
            cells[2],
            cells[3]
        ));
    }
    write_csv("table3.csv", "method,mnist_nc2,mnist_nc5,cifar_nc2,cifar_nc5", &rows);
    println!("paper shape: Nc=2 degrades both methods (hard on cifar); Nc=5");
    println!("recovers most of it; T-FedAvg ~= FedAvg at every cell.");
}

/// Table IV: upstream/downstream MB for 100 rounds, N=100, lambda=0.1.
/// Byte counts come straight from the transport layer's per-round
/// `LinkStats` (frame headers included — this is wire traffic, not an
/// analytic payload estimate), measured over 2 real rounds and
/// extrapolated (payload size per round is constant).
fn table4(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    println!("\n=== Table IV: communication costs, 100 rounds, N=100, lambda=0.1, E=5 ===");
    println!(
        "{:<10} | {:>12} {:>12} | {:>12} {:>12}",
        "method", "mlp up(MB)", "mlp down(MB)", "cnn up(MB)", "cnn down(MB)"
    );
    let rounds_target = 100.0;
    let mut results: Vec<(String, Vec<f64>)> = Vec::new();
    for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
        let mut cells = Vec::new();
        for task in [Task::MnistLike, Task::CifarLike] {
            let mut cfg = ExperimentConfig::large_federation(protocol, task, 3);
            cfg.rounds = 2;
            cfg.local_epochs = 5;
            cfg.eval_every = 5; // skip eval: we only need the byte counts
            cfg.train_samples = 2_000;
            cfg.test_samples = 200;
            if task == Task::CifarLike {
                cfg.batch = 32;
                cfg.local_epochs = 1; // bytes don't depend on E
                cfg.rounds = 1;
                cfg.train_samples = 400;
            }
            let backend = backend_for(engine, &mut cfg);
            let m = run(cfg, backend.as_ref());
            // frame-layer totals recorded per round by the round driver
            let per_round_up = m.total_up_bytes() as f64 / m.records.len() as f64;
            let per_round_down = m.total_down_bytes() as f64 / m.records.len() as f64;
            let frames = m.total_up_frames() + m.total_down_frames();
            println!(
                "  [{} {:?}] measured {} data frames over {} rounds",
                protocol.name(),
                task,
                frames,
                m.records.len()
            );
            cells.push(per_round_up * rounds_target / (1024.0 * 1024.0));
            cells.push(per_round_down * rounds_target / (1024.0 * 1024.0));
        }
        println!(
            "{:<10} | {:>12.2} {:>12.2} | {:>12.2} {:>12.2}",
            protocol.name(),
            cells[0],
            cells[1],
            cells[2],
            cells[3]
        );
        results.push((protocol.name().to_string(), cells));
    }
    if results.len() == 2 {
        let f = &results[0].1;
        let t = &results[1].1;
        println!(
            "compression ratio: mlp up {:.1}x down {:.1}x | cnn up {:.1}x down {:.1}x",
            f[0] / t[0],
            f[1] / t[1],
            f[2] / t[2],
            f[3] / t[3]
        );
        let rows: Vec<String> = results
            .iter()
            .map(|(n, c)| format!("{},{:.3},{:.3},{:.3},{:.3}", n, c[0], c[1], c[2], c[3]))
            .collect();
        write_csv("table4.csv", "method,mlp_up_mb,mlp_down_mb,cnn_up_mb,cnn_down_mb", &rows);
    }
    println!("paper shape: FedAvg 742.49/742.49 MB (MLP), 18525.7/18525.7 MB (ResNet*);");
    println!("T-FedAvg ~1/16 of both directions (46.41 / 1157.86 MB).");
}

/// Codec comparison: the same Table-II experiment under every registered
/// payload codec, bytes measured by the transport layer's `LinkStats`.
/// Emits bench_out/compression.csv and BENCH_compression.json (repo root)
/// so the perf trajectory is machine-tracked from this PR onward.
fn compression(engine: &Option<std::sync::Arc<tfed::runtime::Engine>>) {
    use tfed::util::json::{num, obj, s};

    println!("\n=== Compression: per-codec wire traffic, identical experiment ===");
    println!(
        "{:<12} {:<10} {:>9} {:>14} {:>14} {:>9} {:>10}",
        "codec", "protocol", "best_acc", "up (B/round)", "down (B/round)", "ratio", "s/round"
    );
    let codecs =
        ["dense", "fp16", "quant8", "quant4", "quant1", "stc:k=0.01", "ternary"];
    let mut rows = Vec::new();
    let mut entries = Vec::new();
    let mut ledger_vals = Vec::new();
    let mut dense_up = f64::NAN;
    let mut dense_down = f64::NAN;
    for name in codecs {
        let spec = CodecSpec::parse(name).expect("registered codec");
        let protocol = Protocol::for_codec(spec);
        let mut cfg = bench_cfg(protocol, Task::MnistLike, 42);
        cfg.codec = spec;
        let backend = backend_for(engine, &mut cfg);
        let m = run(cfg, backend.as_ref());
        let rounds = m.records.len() as f64;
        let up = m.total_up_bytes() as f64 / rounds;
        let down = m.total_down_bytes() as f64 / rounds;
        if name == "dense" {
            dense_up = up;
            dense_down = down;
        }
        let ratio = (dense_up + dense_down) / (up + down);
        let wall = m.total_wall_secs() / rounds;
        println!(
            "{:<12} {:<10} {:>8.2}% {:>14.0} {:>14.0} {:>8.1}x {:>10.3}",
            name,
            protocol.name(),
            m.best_acc() * 100.0,
            up,
            down,
            ratio,
            wall
        );
        rows.push(format!(
            "{},{},{:.4},{:.0},{:.0},{:.2},{:.4}",
            name,
            protocol.name(),
            m.best_acc(),
            up,
            down,
            ratio,
            wall
        ));
        entries.push((
            name,
            obj(vec![
                ("protocol", s(protocol.name())),
                ("best_acc", num(m.best_acc() as f64)),
                ("up_bytes_per_round", num(up)),
                ("down_bytes_per_round", num(down)),
                ("compression_ratio_vs_dense", num(ratio)),
                ("round_wall_secs", num(wall)),
            ]),
        ));
        ledger_vals.push((format!("{name}/best_acc"), m.best_acc() as f64));
        ledger_vals.push((format!("{name}/up_bytes_per_round"), up));
        ledger_vals.push((format!("{name}/down_bytes_per_round"), down));
        ledger_vals.push((format!("{name}/compression_ratio_vs_dense"), ratio));
    }
    write_csv(
        "compression.csv",
        "codec,protocol,best_acc,up_bytes_per_round,down_bytes_per_round,ratio_vs_dense,round_wall_secs",
        &rows,
    );

    // Ternary codec hot loops: pack / unpack / dequantize throughput over
    // a 4M-trit buffer (best of N — the noise-robust statistic). GB/s is
    // measured on the unpacked side: 1 B/trit for the i8 pattern loops,
    // 4 B/trit for the f32 dequantize output.
    let hot_loops = {
        use std::time::Instant;
        use tfed::compress::{pack_ternary, unpack_dequantize, unpack_ternary};
        use tfed::util::rng::Pcg;
        let trits = 4usize << 20;
        let repeats = match scale() {
            Scale::Quick => 3usize,
            Scale::Default => 7,
            Scale::Full => 15,
        };
        let mut rng = Pcg::new(42, 0x7E_44);
        let it: Vec<i8> = (0..trits).map(|_| (rng.below(3) as i8) - 1).collect();
        let packed = pack_ternary(&it);
        let best = |f: &mut dyn FnMut()| -> f64 {
            let mut b = f64::INFINITY;
            for _ in 0..repeats {
                let t0 = Instant::now();
                f();
                b = b.min(t0.elapsed().as_secs_f64());
            }
            b
        };
        let pack_s = best(&mut || {
            std::hint::black_box(pack_ternary(&it));
        });
        let unpack_s = best(&mut || {
            std::hint::black_box(unpack_ternary(&packed).unwrap());
        });
        let deq_s = best(&mut || {
            std::hint::black_box(unpack_dequantize(&packed, 0.05).unwrap());
        });
        let gb = |bytes: usize, secs: f64| bytes as f64 / secs.max(1e-9) / 1e9;
        let pack_gbps = gb(trits, pack_s);
        let unpack_gbps = gb(trits, unpack_s);
        let deq_gbps = gb(4 * trits, deq_s);
        println!(
            "codec hot loops ({}M trits, best of {repeats}): pack {pack_gbps:.2} GB/s, \
             unpack {unpack_gbps:.2} GB/s, dequantize {deq_gbps:.2} GB/s",
            trits >> 20
        );
        ledger_vals.push(("hot_loops/pack_gbps".to_string(), pack_gbps));
        ledger_vals.push(("hot_loops/unpack_gbps".to_string(), unpack_gbps));
        ledger_vals.push(("hot_loops/dequantize_gbps".to_string(), deq_gbps));
        obj(vec![
            ("trits", num(trits as f64)),
            ("pack_gbps", num(pack_gbps)),
            ("unpack_gbps", num(unpack_gbps)),
            ("dequantize_gbps", num(deq_gbps)),
        ])
    };

    let doc = obj(vec![
        ("bench", s("paper_tables --compression")),
        ("baseline", s("dense")),
        ("scale", s(scale_name())),
        ("codecs", obj(entries)),
        ("hot_loops", hot_loops),
    ]);
    // land next to ROADMAP.md when run via `cargo bench` (cwd = rust/)
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_compression.json"
    } else {
        "BENCH_compression.json"
    };
    std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_compression.json");
    println!("  -> wrote {path}");
    append_bench("compression", &ledger_vals);
    println!("shape: ternary/quant1 ~16x, stc(1%) deepest, fp16 2x, quant8 ~4x;");
    println!("accuracy within a few points of dense for every codec at this scale.");
}

/// Native training throughput: the layer-graph trainer over every
/// registry model x mode x kernel config, measured as samples/sec and
/// µs per local round (one epoch over the workload in batches of 64).
/// The naive seed kernels are the baseline row; the blocked/threaded
/// kernels must produce bit-identical parameters (asserted here — the
/// speedup is free, not a different computation). Emits
/// bench_out/train.csv and BENCH_train.json (repo root), giving the perf
/// trajectory its training-throughput series. Also measures the
/// obs-enabled overhead on the largest model and asserts the <2%
/// standing contract (DESIGN.md §11).
fn train() {
    use std::time::Instant;
    use tfed::model::{init_params, registry};
    use tfed::native::{KernelPolicy, LayerGraph, Mode};
    use tfed::util::json::{num, obj, s, Json};
    use tfed::util::rng::Pcg;

    println!("\n=== Train: native layer-graph throughput ===");
    let (rounds, samples) = match scale() {
        Scale::Quick => (1usize, 256usize),
        Scale::Default => (3, 1024),
        Scale::Full => (8, 2048),
    };
    let batch = 64usize;
    let lr = 0.05f32;
    let configs: &[(&str, KernelPolicy)] = &[
        ("naive", KernelPolicy::reference()),
        ("blocked-1t", KernelPolicy::threaded(1)),
        ("blocked-2t", KernelPolicy::threaded(2)),
        ("blocked-4t", KernelPolicy::threaded(4)),
    ];
    // The packed tier computes on the 2-bit cells: a different (but
    // contracted, DESIGN.md §15) float-op order, so it carries its own
    // bit-identity reference (packed-naive) instead of joining the fp
    // chain. Quantized modes only — fp layers have no cells to pack.
    let packed_configs: &[(&str, KernelPolicy)] = &[
        ("packed-naive", KernelPolicy::packed_reference()),
        ("packed-1t", KernelPolicy::packed(1)),
        ("packed-2t", KernelPolicy::packed(2)),
        ("packed-4t", KernelPolicy::packed(4)),
    ];
    println!(
        "{:<10} {:<5} {:<11} {:>13} {:>13} {:>9}",
        "model", "mode", "kernels", "samples/sec", "us/round", "speedup"
    );
    let mut rows = Vec::new();
    let mut model_entries = Vec::new();
    let mut ledger_vals = Vec::new();
    for model in ["mlp", "mlp-large", "cnn"] {
        let def = registry::model_def(model).expect("registry model");
        let dim = def.schema.input_dim;
        let classes = def.schema.num_classes;
        let mut rng = Pcg::new(42, 0xBE_7C);
        let x: Vec<f32> = (0..samples * dim).map(|_| rng.normal()).collect();
        let y: Vec<u32> = (0..samples).map(|i| (i % classes) as u32).collect();
        let mut mode_entries = Vec::new();
        for (mode, mode_name) in [(Mode::Fp, "fp"), (Mode::Fttq, "fttq"), (Mode::Ttq, "ttq")] {
            let mut naive_sps = f64::NAN;
            // one bit-identity reference per tier family: [fp, packed]
            let mut references: [Option<Vec<u32>>; 2] = [None, None];
            let mut kernel_entries = Vec::new();
            let mut mode_configs: Vec<(&str, KernelPolicy)> = configs.to_vec();
            if !matches!(mode, Mode::Fp) {
                mode_configs.extend_from_slice(packed_configs);
            }
            for (label, policy) in &mode_configs {
                let graph = LayerGraph::from_def(&def, mode, 0.05, *policy).expect("graph");
                let mut prng = Pcg::seeded(7);
                let mut params = init_params(&def.schema, &mut prng);
                let mut factors = vec![0.05f32; graph.factors_len()];
                let t0 = Instant::now();
                for _ in 0..rounds {
                    let mut i = 0;
                    while i < samples {
                        let n = batch.min(samples - i);
                        graph
                            .train_batch(
                                &mut params,
                                &mut factors,
                                &x[i * dim..(i + n) * dim],
                                &y[i..i + n],
                                n,
                                lr,
                            )
                            .expect("train_batch");
                        i += n;
                    }
                }
                let secs = t0.elapsed().as_secs_f64().max(1e-9);
                let sps = (rounds * samples) as f64 / secs;
                let us_round = secs * 1e6 / rounds as f64;
                if *label == "naive" {
                    naive_sps = sps;
                }
                let speedup = sps / naive_sps;
                // the whole point of the kernel contract: every config in
                // a tier family is the same computation, down to the last
                // bit — fp configs against naive, packed against its own
                // packed-naive oracle
                let bits: Vec<u32> = params
                    .tensors
                    .iter()
                    .flat_map(|t| t.data.iter().map(|v| v.to_bits()))
                    .chain(factors.iter().map(|v| v.to_bits()))
                    .collect();
                let family = label.starts_with("packed") as usize;
                match &references[family] {
                    None => references[family] = Some(bits),
                    Some(want) => assert_eq!(
                        want, &bits,
                        "{model}/{mode_name}/{label}: kernels diverged from their tier oracle"
                    ),
                }
                println!(
                    "{:<10} {:<5} {:<11} {:>13.0} {:>13.0} {:>8.2}x",
                    model, mode_name, label, sps, us_round, speedup
                );
                rows.push(format!(
                    "{model},{mode_name},{label},{sps:.1},{us_round:.1},{speedup:.3}"
                ));
                kernel_entries.push((
                    *label,
                    obj(vec![
                        ("samples_per_sec", num(sps)),
                        ("us_per_round", num(us_round)),
                        ("speedup_vs_naive", num(speedup)),
                    ]),
                ));
                ledger_vals.push((format!("{model}/{mode_name}/{label}/samples_per_sec"), sps));
            }
            mode_entries.push((
                mode_name,
                obj(vec![
                    ("kernels", obj(kernel_entries)),
                    ("bit_identical", Json::Bool(true)),
                ]),
            ));
        }
        model_entries.push((model, obj(mode_entries)));
    }
    // Quantized inference: forward-only, the packed-ternary GEMM against
    // the fp32 blocked GEMM over each quantized layer's lowered [k, o]
    // matrix (dense: [inp, out]; conv: [kh*kw*cin, cout] — the im2col
    // shape), single-threaded both sides. The packed fast path is
    // asserted bit-identical to its naive packed oracle inline, so the
    // speedup is measured against a contracted float-op order, never an
    // unchecked one (DESIGN.md §15).
    let quantized_inference = {
        use tfed::native::kernels::{self, PackedWeights};
        let reps = match scale() {
            Scale::Quick => 4usize,
            Scale::Default => 16,
            Scale::Full => 48,
        };
        let n = 256usize;
        println!("\n--- quantized inference (fttq forward), {n} rows x {reps} reps ---");
        println!(
            "{:<10} {:>14} {:>14} {:>9}",
            "model", "blocked s/s", "packed s/s", "speedup"
        );
        let mut entries = Vec::new();
        for model in ["mlp-large", "cnn"] {
            let def = registry::model_def(model).expect("registry model");
            // lowered GEMM shape of every quantized weight tensor
            let shapes: Vec<(usize, usize)> = def
                .schema
                .params
                .iter()
                .filter(|p| p.quantized)
                .map(|p| match p.shape.as_slice() {
                    [k, o] => (*k, *o),
                    [kh, kw, cin, cout] => (kh * kw * cin, *cout),
                    other => panic!("unexpected weight shape {other:?}"),
                })
                .collect();
            let wq = 0.05f32;
            let mut rng = Pcg::new(42, 0x9A_11);
            let mut blocked_secs = 0f64;
            let mut packed_secs = 0f64;
            for &(k, o) in &shapes {
                let x: Vec<f32> = (0..n * k).map(|_| rng.normal()).collect();
                let it: Vec<i8> =
                    (0..k * o).map(|_| (rng.below(3) as i8) - 1).collect();
                let w_eff: Vec<f32> = it.iter().map(|&t| t as f32 * wq).collect();
                let b: Vec<f32> = (0..o).map(|_| rng.normal() * 0.1).collect();
                let pw = PackedWeights::from_pattern(&it, k, o);
                let mut out = vec![0f32; n * o];
                let fp1 = KernelPolicy::threaded(1);
                let t0 = Instant::now();
                for _ in 0..reps {
                    kernels::gemm_bias(&x, &w_eff, &b, &mut out, n, k, o, &fp1);
                }
                blocked_secs += t0.elapsed().as_secs_f64();
                let p1 = KernelPolicy::packed(1);
                let t0 = Instant::now();
                for _ in 0..reps {
                    kernels::packed_gemm_bias(&x, &pw, &b, wq, wq, &mut out, n, &p1);
                }
                packed_secs += t0.elapsed().as_secs_f64();
                // inline oracle bit-identity: the fast path must be the
                // packed contract's exact computation on these shapes
                let mut want = vec![0f32; n * o];
                kernels::packed_gemm_bias_naive(&x, &pw, &b, wq, wq, &mut want, n);
                assert_eq!(
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{model} ({k}x{o}): packed forward diverged from its oracle"
                );
            }
            let bsps = (n * reps) as f64 / blocked_secs.max(1e-9);
            let psps = (n * reps) as f64 / packed_secs.max(1e-9);
            let speedup = psps / bsps;
            println!("{model:<10} {bsps:>14.0} {psps:>14.0} {speedup:>8.2}x");
            rows.push(format!("{model},fttq-infer,blocked-1t,{bsps:.1},,1.000"));
            rows.push(format!("{model},fttq-infer,packed-1t,{psps:.1},,{speedup:.3}"));
            entries.push((
                model,
                obj(vec![
                    ("blocked_samples_per_sec", num(bsps)),
                    ("packed_samples_per_sec", num(psps)),
                    ("packed_speedup_vs_blocked", num(speedup)),
                    ("oracle_bit_identical", Json::Bool(true)),
                ]),
            ));
            ledger_vals
                .push((format!("{model}/fttq_infer/blocked_samples_per_sec"), bsps));
            ledger_vals
                .push((format!("{model}/fttq_infer/packed_samples_per_sec"), psps));
            ledger_vals
                .push((format!("{model}/fttq_infer/packed_speedup_vs_blocked"), speedup));
        }
        obj(entries)
    };

    write_csv(
        "train.csv",
        "model,mode,kernels,samples_per_sec,us_per_round,speedup_vs_naive",
        &rows,
    );

    // Observability tax: the same mlp-large/fp round with the obs layer
    // off vs on (per-layer µs counters hot). Min over repeats is the
    // noise-robust statistic; the standing contract (DESIGN.md §11) caps
    // the enabled delta at 2% of round time.
    let obs_overhead = {
        use tfed::obs::trace;
        let def = registry::model_def("mlp-large").expect("registry model");
        let dim = def.schema.input_dim;
        let classes = def.schema.num_classes;
        let mut rng = Pcg::new(42, 0xBE_7C);
        let x: Vec<f32> = (0..samples * dim).map(|_| rng.normal()).collect();
        let y: Vec<u32> = (0..samples).map(|i| (i % classes) as u32).collect();
        let graph = LayerGraph::from_def(&def, Mode::Fp, 0.05, KernelPolicy::threaded(4))
            .expect("graph");
        let repeats = 5usize;
        let us_round = |obs_on: bool| -> f64 {
            if obs_on {
                tfed::obs::enable();
            }
            let mut best = f64::INFINITY;
            for _ in 0..repeats {
                let mut prng = Pcg::seeded(7);
                let mut params = init_params(&def.schema, &mut prng);
                let mut factors = vec![0.05f32; graph.factors_len()];
                let t0 = Instant::now();
                let mut i = 0;
                while i < samples {
                    let n = batch.min(samples - i);
                    graph
                        .train_batch(
                            &mut params,
                            &mut factors,
                            &x[i * dim..(i + n) * dim],
                            &y[i..i + n],
                            n,
                            lr,
                        )
                        .expect("train_batch");
                    i += n;
                }
                best = best.min(t0.elapsed().as_secs_f64() * 1e6);
            }
            // restore the disabled default before the next measurement
            trace::set_enabled(false);
            trace::clear();
            best
        };
        let off = us_round(false);
        let on = us_round(true);
        let delta_pct = (on - off) / off * 100.0;
        println!(
            "obs overhead (mlp-large/fp, min of {repeats}): off {off:.0} us/round, \
             on {on:.0} us/round, delta {delta_pct:+.2}%"
        );
        assert!(
            delta_pct < 2.0,
            "obs-enabled round time regressed {delta_pct:.2}% (contract: <2%, DESIGN.md §11)"
        );
        obj(vec![
            ("model", s("mlp-large")),
            ("us_per_round_off", num(off)),
            ("us_per_round_on", num(on)),
            ("delta_pct", num(delta_pct)),
        ])
    };

    let doc = obj(vec![
        ("bench", s("paper_tables --train")),
        ("scale", s(scale_name())),
        ("batch", num(batch as f64)),
        ("rounds", num(rounds as f64)),
        ("samples_per_round", num(samples as f64)),
        ("models", obj(model_entries)),
        ("quantized_inference", quantized_inference),
        ("obs_overhead", obs_overhead),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_train.json"
    } else {
        "BENCH_train.json"
    };
    std::fs::write(path, doc.to_string_pretty()).expect("write BENCH_train.json");
    println!("  -> wrote {path}");
    append_bench("train", &ledger_vals);
    println!("shape: blocked-4t >= 4x naive on mlp-large (row-parallel + transposed");
    println!("gradient GEMM), identical bits per tier family; mlp is too small to gain");
    println!("much; packed forward beats fp32 blocked on the quantized-inference rows");
    println!("(16x less weight traffic per output).");
}

/// Virtual-time fleet comparison: runs the checked-in
/// `examples/scenarios/sim_fleet.toml` (100k registered clients,
/// heterogeneous device/bandwidth tiers, five codecs, virtual straggler
/// tail) and reports each codec's rounds-per-virtual-hour and simulated
/// time-to-accuracy — the paper's communication claim restated as fleet
/// time. The bench and `tfed run sim_fleet.toml` share one code path and
/// one BENCH_sim.json schema (the scenario bundle with per-cell `sim`
/// blocks), so the artifact never flips shape depending on which tool
/// wrote it last. Also emits bench_out/sim.csv.
fn sim() {
    use tfed::scenario::{run_scenario, ScenarioManifest};

    // cwd is rust/ under `cargo bench`; fall back for repo-root runs
    let (manifest_path, out_path) = if std::path::Path::new("../ROADMAP.md").exists() {
        ("../examples/scenarios/sim_fleet.toml", "../BENCH_sim.json")
    } else {
        ("examples/scenarios/sim_fleet.toml", "BENCH_sim.json")
    };
    let manifest = ScenarioManifest::load(manifest_path).expect("sim_fleet manifest");
    let sim_spec = manifest.sim.as_ref().expect("sim_fleet declares [sim]");
    println!(
        "\n=== Sim: virtual-time fleet, {} registered clients, cohort {} ===",
        sim_spec.registered, sim_spec.cohort
    );
    let results = run_scenario(&manifest).expect("sim_fleet run");

    println!(
        "{:<12} {:<10} {:>9} {:>12} {:>12} {:>14}",
        "codec", "protocol", "best_acc", "vsecs/round", "rounds/vhour", "tta (vsecs)"
    );
    let mut rows = Vec::new();
    let mut ledger_vals = Vec::new();
    for cell in &results.cells {
        let m = &cell.metrics;
        let sim = cell.sim.as_ref().expect("sim cells carry a sim summary");
        let vsecs_per_round = sim.total_sim_secs / m.records.len() as f64;
        let tta = sim.sim_secs_to_target;
        ledger_vals
            .push((format!("{}/rounds_per_virtual_hour", cell.codec), sim.rounds_per_virtual_hour));
        if let Some(t) = tta {
            ledger_vals.push((format!("{}/sim_secs_to_target", cell.codec), t));
        }
        println!(
            "{:<12} {:<10} {:>8.2}% {:>12.1} {:>12.1} {:>14}",
            cell.codec,
            cell.protocol,
            m.best_acc() * 100.0,
            vsecs_per_round,
            sim.rounds_per_virtual_hour,
            tta.map_or("never".to_string(), |t| format!("{t:.1}")),
        );
        rows.push(format!(
            "{},{},{:.4},{:.2},{:.2},{}",
            cell.codec,
            cell.protocol,
            m.best_acc(),
            vsecs_per_round,
            sim.rounds_per_virtual_hour,
            tta.map_or(String::new(), |t| format!("{t:.2}")),
        ));
    }
    write_csv(
        "sim.csv",
        "codec,protocol,best_acc,virtual_secs_per_round,rounds_per_virtual_hour,sim_secs_to_target",
        &rows,
    );
    results.write_json(out_path).expect("write BENCH_sim.json");
    println!("  -> wrote {out_path}");
    append_bench("sim", &ledger_vals);
    println!("shape: compact codecs win transfer time on slow links, so ternary/stc");
    println!("reach the accuracy target in less virtual time than dense/fp16.");
}
