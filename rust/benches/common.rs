//! Shared helpers for the bench binaries (included via #[path]).
// Each bench binary includes this file as a private module and uses a
// different subset of it; silence per-binary dead-code noise.
#![allow(dead_code)]

use std::sync::Arc;

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::{make_backend, Backend};
use tfed::coordinator::run_experiment;
use tfed::eval::RunMetrics;
use tfed::runtime::manifest::default_artifacts_dir;
use tfed::runtime::Engine;

/// Global scale knob: TFED_BENCH_SCALE = quick | default | full.
#[derive(Clone, Copy, PartialEq)]
pub enum Scale {
    Quick,
    Default,
    Full,
}

pub fn scale() -> Scale {
    match std::env::var("TFED_BENCH_SCALE").as_deref() {
        Ok("quick") => Scale::Quick,
        Ok("full") => Scale::Full,
        _ => Scale::Default,
    }
}

/// The active scale as a string (recorded in machine-readable outputs so
/// runs at different scales are never compared apples-to-oranges).
pub fn scale_name() -> &'static str {
    match scale() {
        Scale::Quick => "quick",
        Scale::Default => "default",
        Scale::Full => "full",
    }
}

pub fn engine() -> Option<Arc<Engine>> {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("NOTE: artifacts/ missing — PJRT benches degraded to native backend");
        return None;
    }
    Some(Arc::new(Engine::load(default_artifacts_dir()).expect("engine")))
}

/// Scaled-down Table-II-style config for bench runs (single CPU core).
/// `scale()` stretches rounds/samples toward the paper's setting.
pub fn bench_cfg(protocol: Protocol, task: Task, seed: u64) -> ExperimentConfig {
    let s = scale();
    let mut cfg = ExperimentConfig::table2(protocol, task, seed);
    match task {
        Task::MnistLike => {
            // B=16: T-FedAvg needs many local SGD steps per round to move
            // its sign patterns (the paper's Fig.-7 small-batch advantage)
            cfg.batch = 16;
            cfg.rounds = match s {
                Scale::Quick => 4,
                Scale::Default => 12,
                Scale::Full => 40,
            };
            cfg.train_samples = if s == Scale::Quick { 1_000 } else { 4_000 };
            cfg.test_samples = if s == Scale::Quick { 500 } else { 1_000 };
            cfg.local_epochs = if s == Scale::Quick { 1 } else { 3 };
            cfg.lr = 0.15;
        }
        Task::CifarLike => {
            if !protocol.is_centralized() {
                cfg.n_clients = 2;
            }
            cfg.batch = 32;
            cfg.rounds = match s {
                Scale::Quick => 1,
                Scale::Default => 3,
                Scale::Full => 12,
            };
            cfg.train_samples = match s {
                Scale::Quick => 160,
                Scale::Default => 480,
                Scale::Full => 3_200,
            };
            cfg.test_samples = if s == Scale::Quick { 100 } else { 300 };
            cfg.local_epochs = 1;
            cfg.lr = 0.002;
        }
    }
    cfg
}

/// Build the backend for a config, preferring PJRT when available. With
/// no artifacts, every task runs on the native layer-graph backend —
/// the cifar-like task on the registry `cnn` (the HLO `resnetlite`'s
/// native stand-in), so the paper's second model family no longer drops
/// out of the tables offline.
pub fn backend_for(
    engine: &Option<Arc<Engine>>,
    cfg: &mut ExperimentConfig,
) -> Box<dyn Backend> {
    let use_native = engine.is_none();
    cfg.native_backend = use_native;
    if use_native && cfg.task == Task::CifarLike && cfg.model.is_empty() {
        cfg.model = "cnn".to_string();
    }
    make_backend(engine.clone(), cfg.model_name(), cfg.batch, use_native).expect("backend")
}

pub fn run(cfg: ExperimentConfig, backend: &dyn Backend) -> RunMetrics {
    run_experiment(cfg, backend).expect("experiment")
}

pub fn out_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("bench_out");
    std::fs::create_dir_all(&p).ok();
    p
}

pub fn write_csv(name: &str, header: &str, rows: &[String]) {
    let path = out_dir().join(name);
    let mut s = String::from(header);
    s.push('\n');
    for r in rows {
        s.push_str(r);
        s.push('\n');
    }
    std::fs::write(&path, s).expect("write csv");
    println!("  -> wrote {path:?}");
}

/// Append one bench section's results to the run ledger named by
/// `TFED_LEDGER` (no-op when unset, so default bench runs write exactly
/// the files they always did). Flat `name → value` pairs, e.g.
/// `ternary/up_bytes_per_round`; the perf trajectory `tfed diff` gates
/// on accumulates here.
pub fn append_bench(section: &str, values: &[(String, f64)]) {
    let Ok(path) = std::env::var("TFED_LEDGER") else { return };
    if path.is_empty() || values.is_empty() {
        return;
    }
    let record = tfed::obs::store::bench_record(section, values);
    let appended = tfed::obs::store::Ledger::open(&path)
        .and_then(|ledger| ledger.append(std::slice::from_ref(&record)));
    match appended {
        Ok(()) => println!("  -> appended bench [{section}] to ledger {path}"),
        Err(e) => eprintln!("warning: bench ledger append to {path:?} failed: {e}"),
    }
}

/// Which sections to run: args after `--` (cargo bench -- --table2); empty
/// means all. The `--bench` flag cargo injects is ignored.
pub fn selected_sections() -> Vec<String> {
    std::env::args()
        .skip(1)
        .filter(|a| a != "--bench" && !a.is_empty())
        .map(|a| a.trim_start_matches("--").to_string())
        .collect()
}

pub fn section_enabled(sections: &[String], name: &str) -> bool {
    sections.is_empty() || sections.iter().any(|s| s == name)
}
