//! Microbenchmarks of the system's hot paths (§Perf substrate):
//! codec pack/unpack throughput, message encode/decode, aggregation,
//! quantization, local-epoch latency PJRT vs native.
//!
//!     cargo bench --bench microbench

#[path = "common.rs"]
mod common;

use common::*;
use tfed::comms::{pack_ternary, unpack_dequantize, unpack_ternary, Message};
use tfed::coordinator::backend::{make_backend, TrainMode};
use tfed::coordinator::client::ShardData;
use tfed::coordinator::aggregation::weighted_average;
use tfed::data::synth::SynthSpec;
use tfed::model::{init_params, mlp_schema};
use tfed::quant;
use tfed::util::logging;
use tfed::util::rng::Pcg;
use tfed::util::timer::bench;

fn main() {
    logging::set_level(logging::Level::Warn);
    let sections = selected_sections();
    if section_enabled(&sections, "codec") {
        bench_codec();
    }
    if section_enabled(&sections, "messages") {
        bench_messages();
    }
    if section_enabled(&sections, "server") {
        bench_server_math();
    }
    if section_enabled(&sections, "train") {
        bench_train_paths();
    }
}

fn bench_codec() {
    println!("\n=== codec: 2-bit ternary pack/unpack ===");
    let n = 1_000_000;
    let mut rng = Pcg::seeded(1);
    let it: Vec<i8> = (0..n).map(|_| rng.below(3) as i8 - 1).collect();
    let packed = pack_ternary(&it);

    let r = bench("pack_ternary 1M trits", 50, || {
        std::hint::black_box(pack_ternary(&it));
    });
    println!("{}  ({:.2} GB/s in)", r.line(), n as f64 / r.mean_ns);
    let r = bench("unpack_ternary 1M trits", 50, || {
        std::hint::black_box(unpack_ternary(&packed).unwrap());
    });
    println!("{}  ({:.2} GB/s out)", r.line(), n as f64 / r.mean_ns);
    let r = bench("unpack_dequantize 1M trits", 50, || {
        std::hint::black_box(unpack_dequantize(&packed, 0.05).unwrap());
    });
    println!("{}  ({:.2} GB/s out)", r.line(), n as f64 / r.mean_ns);
}

fn bench_messages() {
    println!("\n=== messages: encode/decode of real update payloads ===");
    let schema = mlp_schema();
    let mut rng = Pcg::seeded(2);
    let params = init_params(&schema, &mut rng);
    let qidx = schema.quantized_indices();
    let mut patterns = Vec::new();
    let mut deltas = Vec::new();
    for &i in &qidx {
        let (it, d) = quant::fttq_quantize(&params.tensors[i].data, 0.05);
        patterns.push(it);
        deltas.push(d);
    }
    let upd = tfed::comms::ternary_update(
        0, 1000, &qidx, &patterns, &[0.1, 0.1, 0.1], &deltas, &params, 1.0,
    );
    let t_msg = Message::TernaryUpdate(upd);
    let t_bytes = t_msg.encode();
    let d_msg = Message::DenseUpdate(tfed::comms::dense_update(0, 1000, &params, 1.0));
    let d_bytes = d_msg.encode();
    println!(
        "payload sizes: ternary {} B vs dense {} B ({:.1}x)",
        t_bytes.len(),
        d_bytes.len(),
        d_bytes.len() as f64 / t_bytes.len() as f64
    );
    let r = bench("encode ternary update (mlp)", 200, || {
        std::hint::black_box(t_msg.encode());
    });
    println!("{}", r.line());
    let r = bench("decode ternary update (mlp)", 200, || {
        std::hint::black_box(Message::decode(&t_bytes).unwrap());
    });
    println!("{}", r.line());
    let r = bench("encode dense update (mlp)", 200, || {
        std::hint::black_box(d_msg.encode());
    });
    println!("{}", r.line());
}

fn bench_server_math() {
    println!("\n=== server math: aggregation + re-quantization ===");
    let schema = mlp_schema();
    let mut rng = Pcg::seeded(3);
    let updates: Vec<(u64, tfed::model::ParamSet)> =
        (0..10).map(|_| (100u64, init_params(&schema, &mut rng))).collect();
    let r = bench("weighted_average 10 mlp clients", 200, || {
        std::hint::black_box(weighted_average(&updates).unwrap());
    });
    println!("{}", r.line());

    let global = init_params(&schema, &mut rng);
    let qidx = schema.quantized_indices();
    let r = bench("server requantize mlp", 200, || {
        std::hint::black_box(quant::requantize_paramset(&global, &qidx, 0.05));
    });
    println!("{}", r.line());

    let w = &global.tensors[0].data;
    let r = bench("fttq_quantize 784x30 layer", 500, || {
        std::hint::black_box(quant::fttq_quantize(w, 0.05));
    });
    println!("{}", r.line());
}

fn bench_train_paths() {
    println!("\n=== local training: PJRT artifact vs native Rust (1 epoch) ===");
    let (train, _) = SynthSpec::mnist_like(1_024, 10, 4).generate();
    let data = ShardData::whole(&train);
    let schema_params = {
        let schema = mlp_schema();
        let mut rng = Pcg::seeded(5);
        init_params(&schema, &mut rng)
    };

    // native path
    let native = make_backend(None, "mlp", 64, true).unwrap();
    let mut rng = Pcg::seeded(6);
    let r = bench("native fttq 1 epoch (1024 samples, B=64)", 8, || {
        let mut rng2 = rng.fork(0);
        std::hint::black_box(
            native
                .train_local(&schema_params, TrainMode::Fttq, &[], &data, 1, 0.1, &mut rng2)
                .unwrap(),
        );
    });
    println!("{}", r.line());

    // PJRT path
    if let Some(engine) = engine() {
        let pjrt = make_backend(Some(engine.clone()), "mlp", 64, false).unwrap();
        // warm the executable cache before timing
        let mut rng2 = rng.fork(1);
        pjrt.train_local(&schema_params, TrainMode::Fttq, &[], &data, 1, 0.1, &mut rng2)
            .unwrap();
        let r = bench("pjrt fttq 1 epoch (1024 samples, B=64)", 8, || {
            let mut rng3 = rng.fork(2);
            std::hint::black_box(
                pjrt.train_local(&schema_params, TrainMode::Fttq, &[], &data, 1, 0.1, &mut rng3)
                    .unwrap(),
            );
        });
        println!("{}", r.line());
        let r = bench("pjrt fp 1 epoch (1024 samples, B=64)", 8, || {
            let mut rng3 = rng.fork(3);
            std::hint::black_box(
                pjrt.train_local(&schema_params, TrainMode::Fp, &[], &data, 1, 0.1, &mut rng3)
                    .unwrap(),
            );
        });
        println!("{}", r.line());
        let test = ShardData::whole(&train);
        let r = bench("pjrt eval 1024 samples", 8, || {
            std::hint::black_box(pjrt.evaluate(&schema_params, &test).unwrap());
        });
        println!("{}", r.line());
        println!("exec counts: {:?}", engine.exec_counts());
    }
}
