"""Pallas kernels for FTTQ ternarization (elementwise + reduction).

TPU-shaped, lowered with interpret=True so they run on any PJRT backend
(real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot run —
see DESIGN.md §Hardware-Adaptation).

Kernels:
  ternary_apply(theta_s, delta, wq)  eqs. 10-12: wq * sign(mask . theta_s)
  abs_sum(theta)                     partial reduction feeding eq. 8
  requantize(theta, delta)           Algorithm 2 downstream: sign w/ fixed Delta

Design notes (TPU thinking, even though we execute interpreted):
  * elementwise kernels stream one (TILE_R, TILE_C) VMEM tile per grid step —
    the VPU shape is (8, 128); tiles are multiples of that.
  * scalars (delta, wq) ride along as (1, 1) blocks mapped to every grid
    step, the Pallas idiom closest to SMEM scalar operands.
  * the eq.-8 reduction is two-stage: a grid of per-tile |x| partial sums,
    then a scalar combine in jnp — the TPU analogue of a block-level
    tree reduction (no warp shuffles here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-friendly tile shape: multiples of the (8, 128) VPU lane grid. §Perf:
# tiles were originally (8, 128); a 784x30 layer then becomes a 98-step
# grid, and interpret-mode lowering unrolls every step into its own
# dynamic-slice/compute/update sequence (~2.1 ms per kernel call). (512,
# 128) tiles keep VMEM per step at 256 KB (f32, well inside a 16 MB VMEM
# with double buffering) and collapse the paper-scale layers to 1-2 grid
# steps (~70x faster on the CPU interpret path, same TPU validity).
TILE_R = 512
TILE_C = 128


def _pad2d(x: jnp.ndarray, tr: int, tc: int) -> jnp.ndarray:
    r, c = x.shape
    pr = (-r) % tr
    pc = (-c) % tc
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def _as2d(x: jnp.ndarray):
    """View any-rank array as 2D (rows, lanes) for tiling; returns undo info."""
    shape = x.shape
    if x.ndim == 2:
        return x, shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = min(n, TILE_C)
    rows = -(-n // cols)
    pad = rows * cols - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, cols), shape


def _ternary_apply_kernel(theta_ref, delta_ref, wq_ref, out_ref):
    t = theta_ref[...]
    delta = delta_ref[0, 0]
    wq = wq_ref[0, 0]
    mask = (jnp.abs(t) > delta).astype(t.dtype)
    out_ref[...] = wq * jnp.sign(t) * mask


def ternary_apply(theta_s: jnp.ndarray, delta, wq) -> jnp.ndarray:
    """theta_t = wq * sign(step(|theta_s| - Delta) . theta_s) (eqs. 10-12)."""
    dtype = theta_s.dtype
    x2d, orig_shape = _as2d(theta_s)
    x = _pad2d(x2d, TILE_R, TILE_C)
    r, c = x.shape
    grid = (r // TILE_R, c // TILE_C)
    delta_arr = jnp.asarray(delta, dtype).reshape(1, 1)
    wq_arr = jnp.asarray(wq, dtype).reshape(1, 1)
    out = pl.pallas_call(
        _ternary_apply_kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)),
        interpret=True,
    )(x, delta_arr, wq_arr)
    out = out[: x2d.shape[0], : x2d.shape[1]]
    if out.shape == orig_shape:
        return out
    n = 1
    for d in orig_shape:
        n *= d
    return out.reshape(-1)[:n].reshape(orig_shape)


def _abs_sum_kernel(theta_ref, out_ref):
    # f32 accumulation regardless of input dtype (bf16-safe).
    out_ref[0, 0] = jnp.sum(jnp.abs(theta_ref[...]).astype(jnp.float32))


def abs_sum(theta: jnp.ndarray) -> jnp.ndarray:
    """sum(|theta|) via a two-stage grid reduction; returns f32 scalar."""
    x2d, _ = _as2d(theta)
    x = _pad2d(x2d, TILE_R, TILE_C)
    r, c = x.shape
    grid = (r // TILE_R, c // TILE_C)
    partials = pl.pallas_call(
        _abs_sum_kernel,
        out_shape=jax.ShapeDtypeStruct(grid, jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        interpret=True,
    )(x)
    return jnp.sum(partials)


def abs_mean(theta: jnp.ndarray) -> jnp.ndarray:
    """mean(|theta|) over the *unpadded* element count (eq. 8 reduction)."""
    n = 1
    for d in theta.shape:
        n *= d
    return (abs_sum(theta) / jnp.float32(n)).astype(theta.dtype)


def threshold_mean(theta_s: jnp.ndarray, t) -> jnp.ndarray:
    """Delta = T * mean(|theta_s|) (eq. 8), kernel-backed."""
    return (jnp.asarray(t, theta_s.dtype) * abs_mean(theta_s)).astype(theta_s.dtype)


def requantize(theta: jnp.ndarray, delta) -> jnp.ndarray:
    """Algorithm 2 downstream step: sign(step(|theta| - Delta) . theta)."""
    return ternary_apply(theta, delta, jnp.ones((), theta.dtype))


def fttq_quantize(theta: jnp.ndarray, wq, t):
    """Kernel-backed FTTQ forward: scale -> eq.8 threshold -> ternarize.

    Returns (theta_t, it, delta); matches kernels.ref.fttq_quantize.
    """
    m = jnp.max(jnp.abs(theta))
    theta_s = theta / jnp.maximum(m, jnp.finfo(theta.dtype).tiny)
    delta = threshold_mean(theta_s, t)
    it = ternary_apply(theta_s, delta, jnp.ones((), theta.dtype))
    return (jnp.asarray(wq, theta.dtype) * it).astype(theta.dtype), it, delta
