"""Pallas tiled matmul against ternarized weights — the forward hot-spot.

x[B, I] @ w[I, O] where w has already been ternarized (values {-wq, 0, +wq}).
This is where the paper's clients spend their FLOPs; on TPU it maps to the
MXU systolic array:

  * 3D grid (m, n, k): each (m, n) output tile accumulates over the k axis.
  * block sizes default to (128, 128, 128) clipped to the padded operand —
    the MXU native tile is 128x128; bf16 inputs with f32 accumulation is
    the MXU contract, so the scratch accumulator is always f32.
  * the k-loop is the innermost grid axis, so each output VMEM tile is
    initialized at k == 0 and flushed implicitly at the last k step —
    the BlockSpec equivalent of the CUDA shared-memory pipelined loop.

Lowered with interpret=True for CPU-PJRT execution (DESIGN.md §Hardware-
Adaptation); correctness vs kernels.ref.ternary_matmul is pytest-enforced.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128
DEFAULT_BN = 128
# §Perf: BK=128 made the 784-deep MLP matmul a 7-step accumulation loop;
# BK=896 (7x128, ~1 MB VMEM for the operand tiles) collapses it to one MXU
# pass per output tile. Still a multiple of the 128 lane width.
DEFAULT_BK = 896


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def _pad_to(x: jnp.ndarray, r: int, c: int) -> jnp.ndarray:
    pr = (-x.shape[0]) % r
    pc = (-x.shape[1]) % c
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def _pallas_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jnp.ndarray:
    """x[B, I] @ w[I, O] with MXU-shaped Pallas tiling, f32 accumulation."""
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0], (
        f"bad matmul shapes {x.shape} @ {w.shape}"
    )
    out_dtype = x.dtype
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, max(8, -(-m // 8) * 8))
    bn = min(bn, max(128, -(-n // 128) * 128))
    bk = min(bk, max(128, -(-k // 128) * 128))
    xp = _pad_to(x, bm, bk)
    wp = _pad_to(w, bk, bn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(xp, wp)
    return out[:m, :n].astype(out_dtype)


# Reverse-mode AD cannot see through pallas_call; the backward pass is the
# pair of transposed matmuls, themselves run through the same Pallas kernel
# (exactly how a production TPU kernel ships fwd + bwd kernels).
@jax.custom_vjp
def ternary_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return _pallas_matmul(x, w)


def _tmm_fwd(x, w):
    return _pallas_matmul(x, w), (x, w)


def _tmm_bwd(res, g):
    x, w = res
    dx = _pallas_matmul(g, w.T)
    dw = _pallas_matmul(x.T, g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


ternary_matmul.defvjp(_tmm_fwd, _tmm_bwd)


def vmem_bytes_estimate(bm: int, bn: int, bk: int, itemsize: int = 4) -> int:
    """Static VMEM footprint of one grid step (x, w, o tiles), for DESIGN §Perf."""
    return itemsize * (bm * bk + bk * bn) + 4 * bm * bn


def mxu_utilization_estimate(m: int, k: int, n: int, bm: int = DEFAULT_BM,
                             bn: int = DEFAULT_BN, bk: int = DEFAULT_BK) -> float:
    """Fraction of MXU lanes doing useful work (padding waste), for §Perf."""
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    return (m * k * n) / float(mp * kp * np_)
