"""Pure-jnp oracle for the L1 Pallas kernels.

Every kernel in this package has a reference implementation here, written in
the most direct jnp form of the paper's equations (Xu et al., TNNLS 2020).
pytest sweeps shapes/dtypes and asserts the Pallas kernels match these.

Equation map (paper section III-A):
  eq. 6   scale()            theta_s = g(theta), layer-wise map to [-1, 1]
  eq. 7   threshold_max()    Delta = T * max|theta_s|
  eq. 8   threshold_mean()   Delta = (T/m) * sum|theta_s|
  eq. 10  mask = step(|theta_s| - Delta)
  eq. 11  I_t  = sign(mask * theta_s)
  eq. 12  theta_t = w_q * I_t
"""

from __future__ import annotations

import jax.numpy as jnp


def scale(theta: jnp.ndarray) -> jnp.ndarray:
    """Layer-wise scaling g: R^n -> [-1, 1] (eq. 6).

    Divides by max|theta| over the whole layer. A zero layer maps to zero
    (guarded so HLO never divides by zero).
    """
    m = jnp.max(jnp.abs(theta))
    return theta / jnp.maximum(m, jnp.finfo(theta.dtype).tiny)


def threshold_max(theta_s: jnp.ndarray, t) -> jnp.ndarray:
    """Delta = T * max(|theta_s|) (eq. 7, the TTQ/TWN heuristic)."""
    return t * jnp.max(jnp.abs(theta_s))


def threshold_mean(theta_s: jnp.ndarray, t) -> jnp.ndarray:
    """Delta = (T/m) * sum(|theta_s|) (eq. 8, the paper's criterion).

    Sparsity-aware: a mostly-zero layer gets a lower threshold than eq. 7
    would give, avoiding the homogeneity problem described after eq. 7.
    """
    return t * jnp.mean(jnp.abs(theta_s))


def abs_mean(theta: jnp.ndarray) -> jnp.ndarray:
    """mean(|theta|) — the reduction inside eq. 8."""
    return jnp.mean(jnp.abs(theta))


def ternarize(theta_s: jnp.ndarray, delta, wq) -> jnp.ndarray:
    """theta_t = w_q * sign(step(|theta_s| - Delta) * theta_s) (eqs. 10-12).

    step(0) convention: the paper's epsilon is the Heaviside step; we use
    strict `|x| > Delta` so that Delta == 0 keeps exact zeros at zero,
    matching sign(0) == 0 in eq. 11.
    """
    mask = (jnp.abs(theta_s) > delta).astype(theta_s.dtype)
    return (wq * jnp.sign(theta_s) * mask).astype(theta_s.dtype)


def ternary_indices(theta_s: jnp.ndarray, delta):
    """(I_p, I_n) membership masks (eqs. 13-14)."""
    return theta_s > delta, theta_s < -delta


def requantize(theta: jnp.ndarray, delta) -> jnp.ndarray:
    """Server-side re-quantization (Algorithm 2, downstream step).

    sign(step(|theta| - Delta) * theta) with a *fixed* Delta (paper default
    0.05) applied to the normalized global model. Output values are in
    {-1, 0, +1}; no scaling factor — the downstream payload is pure ternary.
    """
    return ternarize(theta, delta, jnp.ones((), theta.dtype))


def ternary_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x @ w where w is a ternarized weight matrix (values {-wq, 0, +wq}).

    The oracle is just a dense matmul; the Pallas kernel tiles it for the
    MXU. Accumulation is f32 regardless of input dtype.
    """
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    return acc.astype(x.dtype)


def fttq_quantize(theta: jnp.ndarray, wq, t):
    """Full FTTQ forward for one layer: scale -> threshold -> ternarize.

    Returns (theta_t, it, delta) where it = sign-pattern in {-1, 0, +1}
    and theta_t = wq * it (eq. 12).
    """
    theta_s = scale(theta)
    delta = threshold_mean(theta_s, t)
    it = ternarize(theta_s, delta, jnp.ones((), theta.dtype))
    return wq * it, it, delta
