"""L1: Pallas kernels for FTTQ ternarization + ternary matmul.

`ref` holds the pure-jnp oracles; `ternary` / `ternary_matmul` the Pallas
implementations (interpret=True). See DESIGN.md §Layer-1.
"""
from . import ref  # noqa: F401
from .ternary import (  # noqa: F401
    abs_mean,
    abs_sum,
    fttq_quantize,
    requantize,
    ternary_apply,
    threshold_mean,
)
from .ternary_matmul import ternary_matmul  # noqa: F401
