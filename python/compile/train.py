"""L2 training/eval graph builders — the functions aot.py lowers to HLO.

Each builder returns (fn, input_spec, output_spec) where fn takes/returns
*positional* arrays only (no pytrees in the signature), so the HLO parameter
order is exactly the spec order and the Rust runtime marshals by index.

Graphs are *epoch-granular*: `lax.scan` over NB fixed-size batches with a
per-sample {0,1} mask (padding => unbalanced client shards supported), so
one PJRT call executes one local epoch — Python never appears at runtime.

Modes:
  fp    — full-precision local epoch       (Baseline / FedAvg clients)
  fttq  — FTTQ quantization-aware epoch    (T-FedAvg clients; paper Alg. 1)
  ttq   — two-factor TTQ epoch             (TTQ baseline; Figs. 12-13)
"""

from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import fttq as fttq_mod
from . import optim as optim_mod
from .models import ModelDef


def _masked_ce(logits: jnp.ndarray, y: jnp.ndarray, m: jnp.ndarray):
    """(sum of masked CE loss, sum of mask). y: int32 labels, m: {0,1} f32."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    return jnp.sum(nll * m), jnp.sum(m)


def _epoch_scan(step_fn, carry, xs, ys, ms):
    """scan step_fn over the batch axis; returns (carry, mean masked loss)."""
    (carry, (loss_sum, mask_sum)) = lax.scan(
        lambda c, b: step_fn(c, *b), carry, (xs, ys, ms))
    return carry, loss_sum / jnp.maximum(mask_sum, 1.0)


def _scan_accumulate(step_fn, carry, batches):
    def body(c_acc, b):
        c, (ls, ms_) = c_acc
        c, (dls, dms) = step_fn(c, *b)
        return (c, (ls + dls, ms_ + dms)), None

    (carry, (loss_sum, mask_sum)), _ = lax.scan(
        body, (carry, (jnp.zeros(()), jnp.zeros(()))), batches)
    return carry, loss_sum, mask_sum


# ---------------------------------------------------------------------------
# train-epoch builders
# ---------------------------------------------------------------------------

def build_fp_train_epoch(model: ModelDef, optimizer: optim_mod.Optimizer,
                         batch: int, nb: int):
    """Full-precision local epoch (FedAvg / centralized baseline)."""
    spec = model.spec()
    n_params = len(spec)
    opt_spec = optimizer.state_spec(spec)
    n_opt = len(opt_spec)

    def fn(*args):
        params = list(args[:n_params])
        opt = list(args[n_params:n_params + n_opt])
        xs, ys, ms, lr = args[n_params + n_opt:]

        def loss_fn(params, x, y, m):
            logits = model.apply_fp(params, x)
            ls, msum = _masked_ce(logits, y, m)
            return ls / jnp.maximum(msum, 1.0), (ls, msum)

        def step(carry, x, y, m):
            params, opt = carry
            (_, (ls, msum)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, x, y, m)
            params, opt = optimizer.update(params, grads, opt, lr)
            return (params, opt), (ls, msum)

        (params, opt), loss_sum, mask_sum = _scan_accumulate(
            step, (params, opt), (xs, ys, ms))
        mean_loss = loss_sum / jnp.maximum(mask_sum, 1.0)
        return tuple(params) + tuple(opt) + (mean_loss,)

    in_spec = (
        spec
        + opt_spec
        + [{"name": "xs", "shape": [nb, batch, model.input_dim]},
           {"name": "ys", "shape": [nb, batch], "dtype": "s32"},
           {"name": "ms", "shape": [nb, batch]},
           {"name": "lr", "shape": []}]
    )
    out_spec = spec + opt_spec + [{"name": "mean_loss", "shape": []}]
    return fn, in_spec, out_spec


def build_fttq_train_epoch(model: ModelDef, optimizer: optim_mod.Optimizer,
                           batch: int, nb: int, t: float = 0.05,
                           wq_grad: str = "paper", use_pallas: bool = True):
    """FTTQ quantization-aware local epoch (paper Algorithm 1).

    Extra trained input: wq vector, one factor per quantized layer.
    """
    spec = model.spec()
    n_params = len(spec)
    n_q = model.num_quantized()
    wq_spec = [{"name": "wq", "shape": [n_q]}]
    # optimizer state covers params + wq (wq is trained like any parameter)
    opt_spec = optimizer.state_spec(spec + wq_spec)
    n_opt = len(opt_spec)
    quantizer = fttq_mod.make_fttq(t=t, wq_grad=wq_grad, use_pallas=use_pallas)

    def fn(*args):
        params = list(args[:n_params])
        wq = args[n_params]
        opt = list(args[n_params + 1:n_params + 1 + n_opt])
        xs, ys, ms, lr = args[n_params + 1 + n_opt:]

        def loss_fn(params_wq, x, y, m):
            params, wq = params_wq
            forward = model.apply_quantized(params, wq, quantizer)
            ls, msum = _masked_ce(forward(x), y, m)
            return ls / jnp.maximum(msum, 1.0), (ls, msum)

        def step(carry, x, y, m):
            params, wq, opt = carry
            (_, (ls, msum)), (g_params, g_wq) = jax.value_and_grad(
                loss_fn, has_aux=True)((params, wq), x, y, m)
            all_params, all_grads = params + [wq], g_params + [g_wq]
            new_all, opt = optimizer.update(all_params, all_grads, opt, lr)
            return (new_all[:-1], new_all[-1], opt), (ls, msum)

        (params, wq, opt), loss_sum, mask_sum = _scan_accumulate(
            step, (params, wq, opt), (xs, ys, ms))
        mean_loss = loss_sum / jnp.maximum(mask_sum, 1.0)
        return tuple(params) + (wq,) + tuple(opt) + (mean_loss,)

    in_spec = (
        spec + wq_spec + opt_spec
        + [{"name": "xs", "shape": [nb, batch, model.input_dim]},
           {"name": "ys", "shape": [nb, batch], "dtype": "s32"},
           {"name": "ms", "shape": [nb, batch]},
           {"name": "lr", "shape": []}]
    )
    out_spec = spec + wq_spec + opt_spec + [{"name": "mean_loss", "shape": []}]
    return fn, in_spec, out_spec


def build_ttq_train_epoch(model: ModelDef, optimizer: optim_mod.Optimizer,
                          batch: int, nb: int, t: float = 0.05,
                          use_pallas: bool = True):
    """Two-factor TTQ epoch (baseline; tracks wp/wn for Figs. 12-13)."""
    spec = model.spec()
    n_params = len(spec)
    n_q = model.num_quantized()
    quantizer = fttq_mod.make_ttq(t=t, use_pallas=use_pallas)

    factor_spec = [{"name": "wp", "shape": [n_q]}, {"name": "wn", "shape": [n_q]}]
    opt_spec = optimizer.state_spec(spec + factor_spec)
    n_opt = len(opt_spec)

    def fn(*args):
        params = list(args[:n_params])
        wp, wn = args[n_params], args[n_params + 1]
        opt = list(args[n_params + 2:n_params + 2 + n_opt])
        xs, ys, ms, lr = args[n_params + 2 + n_opt:]

        def q_layer(w, p, n):
            return quantizer(w, p, n)

        def loss_fn(pw, x, y, m):
            params, wp, wn = pw
            forward = model.apply_ttq(params, wp, wn, q_layer)
            ls, msum = _masked_ce(forward(x), y, m)
            return ls / jnp.maximum(msum, 1.0), (ls, msum)

        def step(carry, x, y, m):
            params, wp, wn, opt = carry
            (_, (ls, msum)), (gp, gwp, gwn) = jax.value_and_grad(
                loss_fn, has_aux=True)((params, wp, wn), x, y, m)
            all_p = params + [wp, wn]
            all_g = gp + [gwp, gwn]
            new_all, opt = optimizer.update(all_p, all_g, opt, lr)
            return (new_all[:-2], new_all[-2], new_all[-1], opt), (ls, msum)

        (params, wp, wn, opt), loss_sum, mask_sum = _scan_accumulate(
            step, (params, wp, wn, opt), (xs, ys, ms))
        mean_loss = loss_sum / jnp.maximum(mask_sum, 1.0)
        return tuple(params) + (wp, wn) + tuple(opt) + (mean_loss,)

    in_spec = (
        spec + factor_spec + opt_spec
        + [{"name": "xs", "shape": [nb, batch, model.input_dim]},
           {"name": "ys", "shape": [nb, batch], "dtype": "s32"},
           {"name": "ms", "shape": [nb, batch]},
           {"name": "lr", "shape": []}]
    )
    out_spec = spec + factor_spec + opt_spec + [{"name": "mean_loss", "shape": []}]
    return fn, in_spec, out_spec


# ---------------------------------------------------------------------------
# eval / quantize builders
# ---------------------------------------------------------------------------

def build_eval_chunk(model: ModelDef, batch: int, nb: int):
    """scan over eval batches -> (loss_sum, correct, count).

    Takes whatever parameter values it is given — full-precision for
    FedAvg/Baseline, rebuilt ternary (wq * it) for T-FedAvg inference.
    """
    spec = model.spec()
    n_params = len(spec)

    def fn(*args):
        params = list(args[:n_params])
        xs, ys, ms = args[n_params:]

        def step(carry, batch):
            x, y, m = batch
            loss_sum, correct, count = carry
            logits = model.apply_fp(params, x)
            ls, msum = _masked_ce(logits, y, m)
            pred = jnp.argmax(logits, axis=1)
            correct = correct + jnp.sum((pred == y).astype(jnp.float32) * m)
            return (loss_sum + ls, correct, count + msum), None

        (loss_sum, correct, count), _ = lax.scan(
            step, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())),
            (xs, ys, ms))
        return loss_sum, correct, count

    in_spec = (
        spec
        + [{"name": "xs", "shape": [nb, batch, model.input_dim]},
           {"name": "ys", "shape": [nb, batch], "dtype": "s32"},
           {"name": "ms", "shape": [nb, batch]}]
    )
    out_spec = [{"name": "loss_sum", "shape": []},
                {"name": "correct", "shape": []},
                {"name": "count", "shape": []}]
    return fn, in_spec, out_spec


def build_quantize(model: ModelDef, t: float = 0.05, use_pallas: bool = True):
    """Ternarize trained weights for upload: params -> (it..., delta...).

    The sign patterns `it` (values in {-1,0,+1}, f32) are what the Rust
    comms layer packs to 2 bits; wq rides along unchanged in the message.

    Inputs are ONLY the quantized weight tensors: unused HLO parameters get
    pruned during lowering, which would silently break the Rust runtime's
    index-based marshalling if biases were declared but never read.
    """
    from .kernels import ternary as tkern
    from .kernels import ref as kref

    spec = model.spec()
    q_idx = model.quantized_indices()

    def fn(*weights):
        its, deltas = [], []
        for w in weights:
            if use_pallas:
                _, it, delta = tkern.fttq_quantize(w, 1.0, t)
            else:
                _, it, delta = kref.fttq_quantize(w, 1.0, t)
            its.append(it)
            deltas.append(delta)
        return tuple(its) + tuple(deltas)

    in_spec = [spec[i] for i in q_idx]
    out_spec = (
        [{"name": f"it_{spec[i]['name']}", "shape": spec[i]["shape"]} for i in q_idx]
        + [{"name": f"delta_{spec[i]['name']}", "shape": []} for i in q_idx]
    )
    return fn, in_spec, out_spec
