"""AOT entry point: lower every training/eval/quantize graph to HLO text.

Run once at build time (`make artifacts`); the Rust coordinator is
self-contained afterwards. Python never appears on the request path.

Interchange format is HLO **text**, not serialized HloModuleProto: the xla
crate links xla_extension 0.5.1 which rejects jax>=0.5 protos (64-bit
instruction ids); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Outputs:
  artifacts/<name>.hlo.txt   one per (model x mode x batch) + eval + quantize
  artifacts/manifest.json    models, parameter layouts, artifact I/O specs —
                             the single source of truth the Rust runtime loads
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import optim as optim_mod
from . import train as train_mod
from .models import MODELS, ModelDef

# Paper hyperparameters (Table I + section III defaults).
T_K = 0.05            # client threshold hyperparameter T_k (eq. 8)
SERVER_DELTA = 0.05   # fixed downstream re-quantization threshold (Alg. 2)
WQ_GRAD = "paper"     # Algorithm 1 gradient rule (ablation: "symmetric")
WQ_INIT = 0.05        # per-layer w^q initialization (Alg. 2 "initialize w^q")

# Per-model artifact plan. `train_batches` maps B -> NB (samples per
# epoch-chunk call = B*NB); Fig. 7 sweeps B. Learning rates are presets for
# the synthetic datasets (paper values kept in the comment).
MODEL_PLAN = {
    "mlp": {
        "optimizer": "sgd",          # paper: SGD, lr 1e-4 on 60k MNIST
        "default_lr": 0.05,
        "train_batches": {16: 64, 32: 32, 64: 16, 128: 8},
        "eval_batch": (128, 8),
    },
    "resnetlite": {
        "optimizer": "adam",         # paper: Adam, lr 8e-3 on CIFAR10
        "default_lr": 0.002,
        "train_batches": {16: 32, 32: 16, 64: 8},
        "eval_batch": (128, 4),
    },
}

MODES = ("fp", "fttq", "ttq")

_DTYPES = {"f32": jnp.float32, "s32": jnp.int32}


def _to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _arg_specs(in_spec):
    out = []
    for s in in_spec:
        dt = _DTYPES[s.get("dtype", "f32")]
        out.append(jax.ShapeDtypeStruct(tuple(s["shape"]), dt))
    return out


def _norm_spec(spec):
    """Fill in default dtype so the manifest is explicit."""
    return [{"name": s["name"], "shape": list(s["shape"]),
             "dtype": s.get("dtype", "f32"),
             **({"quantized": True} if s.get("quantized") else {})}
            for s in spec]


def _build(model: ModelDef, mode: str, optimizer, batch: int, nb: int):
    if mode == "fp":
        return train_mod.build_fp_train_epoch(model, optimizer, batch, nb)
    if mode == "fttq":
        return train_mod.build_fttq_train_epoch(
            model, optimizer, batch, nb, t=T_K, wq_grad=WQ_GRAD)
    if mode == "ttq":
        return train_mod.build_ttq_train_epoch(model, optimizer, batch, nb, t=T_K)
    raise ValueError(mode)


def emit(out_dir: str, models=None, quick: bool = False,
         verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "t_k": T_K,
        "server_delta": SERVER_DELTA,
        "wq_grad": WQ_GRAD,
        "wq_init": WQ_INIT,
        "models": {},
        "artifacts": {},
    }
    model_names = models or list(MODEL_PLAN)

    def put(name, kind, model_name, mode, batch, nb, fn, in_spec, out_spec):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*_arg_specs(in_spec))
        text = _to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "kind": kind,
            "model": model_name,
            "mode": mode,
            "batch": batch,
            "nb": nb,
            "inputs": _norm_spec(in_spec),
            "outputs": _norm_spec(out_spec),
        }
        if verbose:
            print(f"  {name:<40} {len(text) / 1e6:6.2f} MB hlo  "
                  f"({time.time() - t0:.1f}s)", flush=True)

    for mname in model_names:
        plan = MODEL_PLAN[mname]
        model = MODELS[mname]
        optimizer = optim_mod.make(plan["optimizer"])
        spec = _norm_spec(model.spec())
        n_q = model.num_quantized()
        wq_spec = [{"name": "wq", "shape": [n_q], "dtype": "f32"}]
        ttq_spec = [{"name": "wp", "shape": [n_q], "dtype": "f32"},
                    {"name": "wn", "shape": [n_q], "dtype": "f32"}]
        manifest["models"][mname] = {
            "input_dim": model.input_dim,
            "num_classes": model.num_classes,
            "optimizer": plan["optimizer"],
            "default_lr": plan["default_lr"],
            "params": spec,
            "num_quantized": n_q,
            "opt_state_fp": _norm_spec(optimizer.state_spec(model.spec())),
            "opt_state_fttq": _norm_spec(
                optimizer.state_spec(model.spec() + wq_spec)),
            "opt_state_ttq": _norm_spec(
                optimizer.state_spec(model.spec() + ttq_spec)),
        }
        if verbose:
            print(f"model {mname}: {model.param_count()} params", flush=True)

        batches = plan["train_batches"]
        if quick:
            # smallest batch only, tiny chunk — for fast test builds
            b = min(batches)
            batches = {b: 2}
        for batch, nb in sorted(batches.items()):
            for mode in MODES:
                fn, ins, outs = _build(model, mode, optimizer, batch, nb)
                put(f"{mname}_{mode}_train_b{batch}", "train", mname, mode,
                    batch, nb, fn, ins, outs)

        eb, enb = (min(plan["eval_batch"][0], 32), 2) if quick else plan["eval_batch"]
        fn, ins, outs = train_mod.build_eval_chunk(model, eb, enb)
        put(f"{mname}_eval_b{eb}", "eval", mname, "fp", eb, enb, fn, ins, outs)

        fn, ins, outs = train_mod.build_quantize(model, t=T_K)
        put(f"{mname}_quantize", "quantize", mname, "fttq", 0, 0, fn, ins, outs)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument("--models", nargs="*", default=None,
                   help=f"subset of {list(MODEL_PLAN)}")
    p.add_argument("--quick", action="store_true",
                   help="emit a minimal artifact set (tests)")
    args = p.parse_args(argv)
    t0 = time.time()
    manifest = emit(args.out, models=args.models, quick=args.quick)
    n = len(manifest["artifacts"])
    print(f"wrote {n} artifacts + manifest.json to {args.out} "
          f"in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
