"""Minimal optimizers baked into the AOT training graphs (paper Table I).

Positional-state design (like models.py): optimizer state is a flat list of
arrays so the lowered HLO input/output order is deterministic for Rust.

  SGD  — state []            (paper: MLP on MNIST, lr 1e-4)
  Adam — state [m..., v..., step]  (paper: ResNet* on CIFAR10, lr 8e-3)
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax.numpy as jnp

Params = List[jnp.ndarray]


class Optimizer:
    name: str

    def init_state(self, params: Params) -> Params:
        raise NotImplementedError

    def state_spec(self, param_spec: List[dict]) -> List[dict]:
        """Named layout of the state arrays, for manifest.json."""
        raise NotImplementedError

    def update(self, params: Params, grads: Params, state: Params,
               lr) -> Tuple[Params, Params]:
        raise NotImplementedError


class Sgd(Optimizer):
    name = "sgd"

    def init_state(self, params):
        return []

    def state_spec(self, param_spec):
        return []

    def update(self, params, grads, state, lr):
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return new_params, state


class Adam(Optimizer):
    name = "adam"

    def __init__(self, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
        self.b1, self.b2, self.eps = b1, b2, eps

    def init_state(self, params):
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        return m + v + [jnp.zeros((), jnp.float32)]

    def state_spec(self, param_spec):
        spec = []
        for tag in ("adam_m", "adam_v"):
            for s in param_spec:
                spec.append({"name": f"{tag}_{s['name']}", "shape": s["shape"],
                             "quantized": False})
        spec.append({"name": "adam_step", "shape": [], "quantized": False})
        return spec

    def update(self, params, grads, state, lr):
        n = len(params)
        m, v, step = state[:n], state[n:2 * n], state[2 * n]
        step = step + 1.0
        b1, b2, eps = self.b1, self.b2, self.eps
        new_m = [b1 * mi + (1 - b1) * g for mi, g in zip(m, grads)]
        new_v = [b2 * vi + (1 - b2) * (g * g) for vi, g in zip(v, grads)]
        bc1 = 1.0 - jnp.power(b1, step)
        bc2 = 1.0 - jnp.power(b2, step)
        new_params = [
            p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            for p, mi, vi in zip(params, new_m, new_v)
        ]
        return new_params, new_m + new_v + [step]


OPTIMIZERS = {"sgd": Sgd, "adam": Adam}


def make(name: str) -> Optimizer:
    return OPTIMIZERS[name]()
