"""FTTQ / TTQ quantizers as `jax.custom_vjp` ops (paper Algorithm 1).

The forward path ternarizes a weight layer with the L1 Pallas kernels; the
backward path implements the paper's gradient rules (straight-through
estimation adapted from TTQ [Zhu et al. 2016] to a single factor):

  latent-weight gradient (STE, TTQ rule with one factor):
      dJ/dtheta_i = wq * g_i          for i in I_p  or  i in I_n
                  = g_i               for i in I_z  (|theta_s_i| <= Delta)

  quantization-factor gradient (paper, Algorithm 1):
      dJ/dwq = sum_{i in I_p} g_i                      (mode="paper")
  the full-chain-rule variant (d theta_t / d wq = it):
      dJ/dwq = sum_{i in I_p} g_i - sum_{i in I_n} g_i (mode="symmetric")
  is kept as an ablation (DESIGN.md §5, ablation table).

TTQ's original two-factor quantizer is implemented alongside because it is
a paper baseline and Figs. 12-13 track w_p / w_n convergence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ternary as tkern
from .kernels import ref as kref

# Ablation switch, fixed at lowering time (artifacts bake one mode).
WQ_GRAD_MODES = ("paper", "symmetric")


def _fwd_common(theta, t, use_pallas: bool):
    """scale (eq. 6) -> eq. 8 threshold -> sign pattern it (eq. 11)."""
    theta_s = kref.scale(theta)
    if use_pallas:
        delta = tkern.threshold_mean(theta_s, t)
        it = tkern.ternary_apply(theta_s, delta, jnp.ones((), theta.dtype))
    else:
        delta = kref.threshold_mean(theta_s, t)
        it = kref.ternarize(theta_s, delta, jnp.ones((), theta.dtype))
    return theta_s, delta, it


def make_fttq(t: float = 0.05, wq_grad: str = "paper", use_pallas: bool = True):
    """Build the FTTQ quantizer `q(theta, wq) -> theta_t` for one layer.

    `t` is the client threshold hyperparameter T_k (eq. 8); `wq` is the
    single trained quantization factor (a scalar per layer).
    """
    assert wq_grad in WQ_GRAD_MODES, wq_grad

    @jax.custom_vjp
    def quantize(theta, wq):
        _, _, it = _fwd_common(theta, t, use_pallas)
        return wq * it

    def quantize_fwd(theta, wq):
        _, delta, it = _fwd_common(theta, t, use_pallas)
        return wq * it, (it, wq)

    def quantize_bwd(res, g):
        it, wq = res
        pos = (it > 0).astype(g.dtype)
        neg = (it < 0).astype(g.dtype)
        zero = 1.0 - pos - neg
        # TTQ STE rule, single factor: wq on the ternary support, 1 on zeros.
        g_theta = g * (wq * (pos + neg) + zero)
        # Support-mean normalization: Algorithm 1 writes a raw sum over I_p,
        # but with |I_p| ~ 10^4 elements the factor step explodes for any
        # practical lr (verified empirically — wq diverges to 1e12 within an
        # epoch). Dividing by |I_p| keeps the update at weight scale and is
        # consistent with the optimal-factor mean of eq. 20. Recorded as a
        # reproduction deviation in DESIGN.md §7.
        if wq_grad == "paper":
            g_wq = jnp.sum(g * pos) / jnp.maximum(jnp.sum(pos), 1.0)
        else:
            g_wq = jnp.sum(g * it) / jnp.maximum(jnp.sum(pos + neg), 1.0)
        return g_theta, g_wq.astype(jnp.result_type(wq))

    quantize.defvjp(quantize_fwd, quantize_bwd)
    return quantize


def make_ttq(t: float = 0.05, use_pallas: bool = True):
    """Original two-factor TTQ quantizer `q(theta, wp, wn) -> theta_t`.

    theta_t = wp on I_p, -wn on I_n, 0 on I_z (wp, wn > 0 scalars).
    Gradients per Zhu et al. 2016:
      dJ/dwp =  sum_{I_p} g_i,   dJ/dwn = -sum_{I_n} g_i
      dJ/dtheta = wp*g on I_p, wn*g on I_n, g on I_z.
    Threshold: eq. 5, Delta = t * max|theta_s| (the TTQ heuristic).
    """

    def _fwd(theta):
        theta_s = kref.scale(theta)
        delta = kref.threshold_max(theta_s, t)
        if use_pallas:
            it = tkern.ternary_apply(theta_s, delta, jnp.ones((), theta.dtype))
        else:
            it = kref.ternarize(theta_s, delta, jnp.ones((), theta.dtype))
        return it

    @jax.custom_vjp
    def quantize(theta, wp, wn):
        it = _fwd(theta)
        pos = (it > 0).astype(theta.dtype)
        neg = (it < 0).astype(theta.dtype)
        return wp * pos - wn * neg

    def quantize_fwd(theta, wp, wn):
        it = _fwd(theta)
        pos = (it > 0).astype(theta.dtype)
        neg = (it < 0).astype(theta.dtype)
        return wp * pos - wn * neg, (pos, neg, wp, wn)

    def quantize_bwd(res, g):
        pos, neg, wp, wn = res
        zero = 1.0 - pos - neg
        g_theta = g * (wp * pos + wn * neg + zero)
        # support-mean normalization (see make_fttq for rationale)
        g_wp = jnp.sum(g * pos) / jnp.maximum(jnp.sum(pos), 1.0)
        g_wn = -jnp.sum(g * neg) / jnp.maximum(jnp.sum(neg), 1.0)
        return g_theta, g_wp.astype(jnp.result_type(wp)), g_wn.astype(jnp.result_type(wn))

    quantize.defvjp(quantize_fwd, quantize_bwd)
    return quantize


def quantize_params(params, wqs, t: float = 0.05, use_pallas: bool = True):
    """Ternarize a whole parameter list for upload (weights only).

    params: list of (w, b); wqs: [wq per layer]. Returns (its, wqs, deltas)
    where its are the {-1,0,+1} sign patterns — exactly what the T-FedAvg
    upstream message carries (2-bit its + f32 wq per layer).
    """
    its, deltas = [], []
    for (w, _b), _wq in zip(params, wqs):
        if use_pallas:
            _, it, delta = tkern.fttq_quantize(w, 1.0, t)
        else:
            _, it, delta = kref.fttq_quantize(w, 1.0, t)
        its.append(it)
        deltas.append(delta)
    return its, wqs, deltas
