"""L2 model definitions: MLP (paper Table I) and ResNetLite (ResNet18* stand-in).

Parameters are *positional lists* of arrays (w1, b1, w2, b2, ...) — never
dict pytrees — so the lowered HLO parameter order is trivially deterministic
and the Rust runtime can marshal by index. `spec()` returns the named layout
that aot.py writes into artifacts/manifest.json.

Quantized layers: every weight tensor (matmul + conv kernels); biases stay
full-precision (they are <2% of parameters; DESIGN.md §3 notes the comm
accounting treats them as f32 payload).
"""

from __future__ import annotations

import math
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.ternary_matmul import ternary_matmul

Params = List[jnp.ndarray]


class ModelDef:
    """Static description + pure apply functions for one architecture."""

    name: str
    input_dim: int
    num_classes: int

    def spec(self) -> List[dict]:
        """[{name, shape, quantized}] in positional parameter order."""
        raise NotImplementedError

    def init(self, key) -> Params:
        raise NotImplementedError

    def apply_fp(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        """Full-precision forward -> logits [B, num_classes]."""
        raise NotImplementedError

    def apply_quantized(self, params: Params, wq: jnp.ndarray,
                        quantizer: Callable) -> Callable:
        """Return forward(x) that ternarizes weights with `quantizer`."""
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    def quantized_indices(self) -> List[int]:
        return [i for i, s in enumerate(self.spec()) if s["quantized"]]

    def num_quantized(self) -> int:
        return len(self.quantized_indices())

    def param_count(self) -> int:
        return sum(int(math.prod(s["shape"])) for s in self.spec())


def _uniform_fanin(key, shape, fan_in, dtype=jnp.float32):
    bound = 1.0 / math.sqrt(max(1, fan_in))
    return jax.random.uniform(key, shape, dtype, -bound, bound)


class MLP(ModelDef):
    """784-30-20-10 feedforward net (paper Table I: ~24k params)."""

    name = "mlp"
    input_dim = 28 * 28
    num_classes = 10
    hidden = (30, 20)

    def spec(self):
        dims = [self.input_dim, *self.hidden, self.num_classes]
        out = []
        for li in range(len(dims) - 1):
            out.append({"name": f"w{li+1}", "shape": [dims[li], dims[li+1]],
                        "quantized": True})
            out.append({"name": f"b{li+1}", "shape": [dims[li+1]],
                        "quantized": False})
        return out

    def init(self, key) -> Params:
        dims = [self.input_dim, *self.hidden, self.num_classes]
        params: Params = []
        for li in range(len(dims) - 1):
            key, k1 = jax.random.split(key)
            params.append(_uniform_fanin(k1, (dims[li], dims[li+1]), dims[li]))
            params.append(jnp.zeros((dims[li+1],), jnp.float32))
        return params

    def apply_fp(self, params, x):
        w1, b1, w2, b2, w3, b3 = params
        h = jax.nn.relu(x @ w1 + b1)
        h = jax.nn.relu(h @ w2 + b2)
        return h @ w3 + b3

    def _apply_tern(self, tws, params, x, use_pallas_matmul=True):
        mm = ternary_matmul if use_pallas_matmul else jnp.matmul
        _, b1, _, b2, _, b3 = params
        h = jax.nn.relu(mm(x, tws[0]) + b1)
        h = jax.nn.relu(mm(h, tws[1]) + b2)
        return mm(h, tws[2]) + b3

    def apply_quantized(self, params, wq, quantizer):
        ws = [params[0], params[2], params[4]]
        tws = [quantizer(w, wq[i]) for i, w in enumerate(ws)]

        def forward(x):
            return self._apply_tern(tws, params, x)

        return forward

    def apply_ttq(self, params, wp, wn, quantizer):
        ws = [params[0], params[2], params[4]]
        tws = [quantizer(w, wp[i], wn[i]) for i, w in enumerate(ws)]

        def forward(x):
            return self._apply_tern(tws, params, x)

        return forward


def _conv(x, w, b):
    """3x3 SAME NHWC conv + bias."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _avgpool2(x):
    return lax.reduce_window(
        x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0


class ResNetLite(ModelDef):
    """Reduced residual CNN for the CIFAR10-like task (ResNet18* stand-in).

    conv3x3(3->C) -> [conv3x3 -> relu -> conv3x3 + skip] -> relu
    -> avgpool2 -> avgpool2 -> flatten -> dense(->64) -> dense(->10).
    C = 32 gives ~53k parameters — the same MLP-vs-CNN contrast axis as the
    paper at single-core-feasible scale (DESIGN.md §3 Substitutions).
    """

    name = "resnetlite"
    side = 16
    channels = 3
    c = 32
    fc = 64
    num_classes = 10
    input_dim = side * side * channels

    def spec(self):
        c, fc = self.c, self.fc
        flat = (self.side // 4) * (self.side // 4) * c
        return [
            {"name": "conv1_w", "shape": [3, 3, self.channels, c], "quantized": True},
            {"name": "conv1_b", "shape": [c], "quantized": False},
            {"name": "conv2_w", "shape": [3, 3, c, c], "quantized": True},
            {"name": "conv2_b", "shape": [c], "quantized": False},
            {"name": "conv3_w", "shape": [3, 3, c, c], "quantized": True},
            {"name": "conv3_b", "shape": [c], "quantized": False},
            {"name": "fc1_w", "shape": [flat, fc], "quantized": True},
            {"name": "fc1_b", "shape": [fc], "quantized": False},
            {"name": "fc2_w", "shape": [fc, self.num_classes], "quantized": True},
            {"name": "fc2_b", "shape": [self.num_classes], "quantized": False},
        ]

    def init(self, key) -> Params:
        params: Params = []
        for s in self.spec():
            shape = tuple(s["shape"])
            if s["quantized"]:
                fan_in = math.prod(shape[:-1])
                key, k1 = jax.random.split(key)
                params.append(_uniform_fanin(k1, shape, fan_in))
            else:
                params.append(jnp.zeros(shape, jnp.float32))
        return params

    def _forward(self, ws, params, x, use_pallas_matmul=True):
        mm = ternary_matmul if use_pallas_matmul else jnp.matmul
        b = [params[1], params[3], params[5], params[7], params[9]]
        img = x.reshape(x.shape[0], self.side, self.side, self.channels)
        h = jax.nn.relu(_conv(img, ws[0], b[0]))
        r = jax.nn.relu(_conv(h, ws[1], b[1]))
        r = _conv(r, ws[2], b[2])
        h = jax.nn.relu(h + r)
        h = _avgpool2(_avgpool2(h))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(mm(h, ws[3]) + b[3])
        return mm(h, ws[4]) + b[4]

    def apply_fp(self, params, x):
        ws = [params[0], params[2], params[4], params[6], params[8]]
        return self._forward(ws, params, x, use_pallas_matmul=False)

    def apply_quantized(self, params, wq, quantizer):
        ws = [params[0], params[2], params[4], params[6], params[8]]
        tws = [quantizer(w, wq[i]) for i, w in enumerate(ws)]

        def forward(x):
            return self._forward(tws, params, x)

        return forward

    def apply_ttq(self, params, wp, wn, quantizer):
        ws = [params[0], params[2], params[4], params[6], params[8]]
        tws = [quantizer(w, wp[i], wn[i]) for i, w in enumerate(ws)]

        def forward(x):
            return self._forward(tws, params, x)

        return forward


MODELS = {"mlp": MLP(), "resnetlite": ResNetLite()}
