"""FTTQ / TTQ quantizer properties — the paper's theory, executable.

Covers:
  * Proposition 4.2 (unbiasedness): E[FTTQ(theta)] == E[theta] == 0 for
    theta ~ U(-1, 1).
  * eq. 20 optimality: w* = mean(theta_i, i in I_p) minimizes
    ||theta - w.I_p + w.I_n||^2 against perturbations.
  * Algorithm 1 gradient rules (paper vs symmetric ablation).
  * TTQ two-factor gradients and the Proposition 4.1 convergence trend.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import fttq
from compile.kernels import ref


def _uniform(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1, 1, size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Proposition 4.2 — unbiasedness
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_unbiasedness_uniform_weights(seed):
    """E[FTTQ(theta)] ~= 0 ~= E[theta] for theta ~ U(-1,1) (Prop 4.2)."""
    theta = _uniform((200, 200), seed=seed)
    q = fttq.make_fttq(t=0.05, use_pallas=False)
    # w_q* from eq. 20: mean over I_p of scaled weights
    ts = ref.scale(theta)
    delta = ref.threshold_mean(ts, 0.05)
    wq_star = jnp.mean(jnp.where(ts > delta, ts, 0.0)) / jnp.mean(ts > delta)
    out = q(theta, wq_star)
    n = theta.size
    # mean of the quantizer output is an unbiased estimator of mean(theta);
    # both are O(1/sqrt(n)) around 0.
    assert abs(float(jnp.mean(out))) < 5.0 / np.sqrt(n)
    assert abs(float(jnp.mean(theta))) < 5.0 / np.sqrt(n)


def test_eq20_optimal_factor():
    """w* = mean_{I_p}(theta) minimizes eq. 17/19 for the positive support."""
    theta = _uniform((100, 100), seed=3)
    delta = 0.3
    ip = np.asarray(theta) > delta
    inn = np.asarray(theta) < -delta
    w_star = np.asarray(theta)[ip].mean()

    def cost(wp, wn):
        t = np.where(ip, wp, np.where(inn, -wn, 0.0))
        return ((np.asarray(theta) - t) ** 2).sum()

    wn_star = -np.asarray(theta)[inn].mean()
    c0 = cost(w_star, wn_star)
    for eps in (1e-3, 1e-2, 0.1):
        assert cost(w_star + eps, wn_star) > c0
        assert cost(w_star - eps, wn_star) > c0
        assert cost(w_star, wn_star + eps) > c0
        assert cost(w_star, wn_star - eps) > c0


def test_prop41_symmetric_factors_converge_to_same_value():
    """Prop 4.1: under U(-1,1), w_p* == w_n* (in expectation)."""
    theta = _uniform((400, 400), seed=5)
    delta = 0.3
    arr = np.asarray(theta)
    wp = arr[arr > delta].mean()
    wn = -arr[arr < -delta].mean()
    assert abs(wp - wn) < 0.01  # both ~ (1 + delta) / 2


# ---------------------------------------------------------------------------
# Algorithm 1 gradients
# ---------------------------------------------------------------------------

def test_fttq_forward_is_ternary_times_wq():
    theta = _uniform((30, 40), seed=1)
    q = fttq.make_fttq(t=0.05, use_pallas=True)
    out = np.asarray(q(theta, jnp.float32(0.37)))
    vals = np.unique(out)
    for v in vals:
        assert min(abs(v - c) for c in (-0.37, 0.0, 0.37)) < 1e-6


@pytest.mark.parametrize("use_pallas", [True, False])
def test_fttq_pallas_matches_ref_path(use_pallas):
    theta = _uniform((50, 20), seed=2)
    qp = fttq.make_fttq(t=0.05, use_pallas=True)
    qr = fttq.make_fttq(t=0.05, use_pallas=False)
    np.testing.assert_allclose(qp(theta, 0.4), qr(theta, 0.4), rtol=1e-6)


def test_wq_grad_paper_rule():
    """dJ/dwq = mean over I_p of dJ/dtheta_t (Algorithm 1's sum,
    support-mean normalized — DESIGN.md §7 reproduction deviation)."""
    theta = _uniform((40, 40), seed=4)
    q = fttq.make_fttq(t=0.05, wq_grad="paper", use_pallas=False)
    g_out = _uniform((40, 40), seed=5)  # arbitrary upstream gradient

    def f(wq):
        return jnp.sum(q(theta, wq) * g_out)

    g_wq = jax.grad(f)(jnp.float32(0.5))
    ts = ref.scale(theta)
    delta = ref.threshold_mean(ts, 0.05)
    ip = np.asarray(ts) > float(delta)
    expected = np.asarray(g_out)[ip].sum() / max(1, ip.sum())
    np.testing.assert_allclose(g_wq, expected, rtol=1e-4)


def test_wq_grad_symmetric_rule():
    """ablation: dJ/dwq = mean of g*it over the ternary support."""
    theta = _uniform((40, 40), seed=6)
    q = fttq.make_fttq(t=0.05, wq_grad="symmetric", use_pallas=False)
    g_out = _uniform((40, 40), seed=7)

    def f(wq):
        return jnp.sum(q(theta, wq) * g_out)

    g_wq = jax.grad(f)(jnp.float32(0.5))
    ts = ref.scale(theta)
    delta = ref.threshold_mean(ts, 0.05)
    it = np.sign(np.asarray(ts)) * (np.abs(np.asarray(ts)) > float(delta))
    expected = (np.asarray(g_out) * it).sum() / max(1, (it != 0).sum())
    np.testing.assert_allclose(g_wq, expected, rtol=1e-4)


def test_theta_grad_ste_rule():
    """dJ/dtheta = wq*g on the ternary support, g on the zero region."""
    theta = _uniform((30, 30), seed=8)
    wq = jnp.float32(0.7)
    q = fttq.make_fttq(t=0.3, use_pallas=False)
    g_out = _uniform((30, 30), seed=9)

    def f(theta):
        return jnp.sum(q(theta, wq) * g_out)

    g_theta = np.asarray(jax.grad(f)(theta))
    ts = ref.scale(theta)
    delta = ref.threshold_mean(ts, 0.3)
    support = np.abs(np.asarray(ts)) > float(delta)
    expected = np.where(support, 0.7 * np.asarray(g_out), np.asarray(g_out))
    np.testing.assert_allclose(g_theta, expected, rtol=1e-4)


# ---------------------------------------------------------------------------
# TTQ two-factor
# ---------------------------------------------------------------------------

def test_ttq_forward_values():
    theta = _uniform((30, 30), seed=10)
    q = fttq.make_ttq(t=0.3, use_pallas=False)
    out = np.asarray(q(theta, jnp.float32(0.6), jnp.float32(0.4)))
    for v in np.unique(out):
        assert min(abs(v - c) for c in (-0.4, 0.0, 0.6)) < 1e-6


def test_ttq_grads():
    theta = _uniform((25, 25), seed=11)
    q = fttq.make_ttq(t=0.3, use_pallas=False)
    g_out = _uniform((25, 25), seed=12)

    def f(wp, wn):
        return jnp.sum(q(theta, wp, wn) * g_out)

    gp, gn = jax.grad(f, argnums=(0, 1))(jnp.float32(0.6), jnp.float32(0.4))
    ts = ref.scale(theta)
    delta = ref.threshold_max(ts, 0.3)
    pos = np.asarray(ts) > float(delta)
    neg = np.asarray(ts) < -float(delta)
    np.testing.assert_allclose(
        gp, np.asarray(g_out)[pos].sum() / max(1, pos.sum()), rtol=1e-4)
    np.testing.assert_allclose(
        gn, -np.asarray(g_out)[neg].sum() / max(1, neg.sum()), rtol=1e-4)


def test_quantize_params_packs_weights_only():
    from compile.models import MODELS
    model = MODELS["mlp"]
    params = model.init(jax.random.PRNGKey(0))
    pairs = [(params[0], params[1]), (params[2], params[3]),
             (params[4], params[5])]
    its, wqs, deltas = fttq.quantize_params(pairs, [0.5, 0.5, 0.5])
    assert len(its) == 3 and len(deltas) == 3
    for it in its:
        assert set(np.unique(np.asarray(it))).issubset({-1.0, 0.0, 1.0})
