"""L1 correctness: Pallas kernels vs the pure-jnp oracle (kernels.ref).

hypothesis sweeps shapes/dtypes; every kernel must match ref within dtype
tolerance. This is the CORE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import ternary as tk
from compile.kernels.ternary_matmul import (
    mxu_utilization_estimate,
    ternary_matmul,
    vmem_bytes_estimate,
)

DIMS = st.integers(min_value=1, max_value=200)
SMALL = st.integers(min_value=1, max_value=64)


def _rand(shape, dtype=np.float32, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(dtype))


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# ternary_apply
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(r=DIMS, c=DIMS, seed=st.integers(0, 2**31), wq=st.floats(0.001, 2.0),
       t=st.floats(0.0, 1.0))
def test_ternary_apply_matches_ref(r, c, seed, wq, t):
    th = _rand((r, c), seed=seed)
    ts = ref.scale(th)
    delta = ref.threshold_mean(ts, t)
    got = tk.ternary_apply(ts, delta, wq)
    want = ref.ternarize(ts, delta, jnp.float32(wq))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31))
def test_ternary_apply_1d_and_4d(n, seed):
    # non-2D inputs go through the _as2d path
    th = _rand((n,), seed=seed)
    d = ref.threshold_mean(th, 0.05)
    np.testing.assert_allclose(
        tk.ternary_apply(th, d, 0.7), ref.ternarize(th, d, jnp.float32(0.7)),
        rtol=1e-6)
    th4 = _rand((3, 3, 2, 5), seed=seed + 1)
    d4 = ref.threshold_mean(th4, 0.05)
    np.testing.assert_allclose(
        tk.ternary_apply(th4, d4, 0.7), ref.ternarize(th4, d4, jnp.float32(0.7)),
        rtol=1e-6)


def test_ternary_apply_values_are_ternary():
    th = _rand((64, 64), seed=3)
    out = np.asarray(tk.ternary_apply(th, 0.3, 1.0))
    assert set(np.unique(out)).issubset({-1.0, 0.0, 1.0})


def test_ternary_apply_zero_threshold_keeps_zeros():
    th = jnp.asarray([[0.0, 1.0, -1.0, 0.5]])
    out = np.asarray(tk.ternary_apply(th, 0.0, 1.0))
    np.testing.assert_array_equal(out, [[0.0, 1.0, -1.0, 1.0]])


def test_ternary_apply_bf16():
    th = _rand((40, 40)).astype(jnp.bfloat16)
    d = ref.threshold_mean(th, 0.05)
    got = tk.ternary_apply(th, d, jnp.bfloat16(0.5)).astype(np.float32)
    want = ref.ternarize(th, d, jnp.bfloat16(0.5)).astype(np.float32)
    np.testing.assert_allclose(got, want, **_tol(jnp.bfloat16))


# ---------------------------------------------------------------------------
# abs reduction / thresholds
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(r=DIMS, c=DIMS, seed=st.integers(0, 2**31))
def test_abs_mean_matches_ref(r, c, seed):
    th = _rand((r, c), seed=seed)
    np.testing.assert_allclose(tk.abs_mean(th), ref.abs_mean(th),
                               rtol=1e-5, atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31),
       t=st.floats(0.0, 1.0))
def test_threshold_mean_matches_ref(n, seed, t):
    th = _rand((n,), seed=seed)
    np.testing.assert_allclose(tk.threshold_mean(th, t),
                               ref.threshold_mean(th, t), rtol=1e-5, atol=1e-7)


def test_threshold_mean_is_bounded_by_tk():
    # eq. 9: Delta <= T_k when theta is scaled to [-1, 1]
    th = ref.scale(_rand((100, 100), seed=7))
    for t in (0.05, 0.3, 0.7, 1.0):
        assert float(tk.threshold_mean(th, t)) <= t + 1e-6


def test_abs_sum_padding_exact():
    # padding must not leak into the sum: prime-ish sizes
    th = _rand((13, 131), seed=11)
    np.testing.assert_allclose(tk.abs_sum(th), np.abs(np.asarray(th)).sum(),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# requantize (server downstream step, Algorithm 2)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(r=SMALL, c=SMALL, seed=st.integers(0, 2**31),
       delta=st.floats(0.0, 0.5))
def test_requantize_matches_ref(r, c, seed, delta):
    th = ref.scale(_rand((r, c), seed=seed))
    np.testing.assert_allclose(tk.requantize(th, delta),
                               ref.requantize(th, jnp.float32(delta)), rtol=1e-6)


# ---------------------------------------------------------------------------
# ternary_matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31))
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand((m, k), seed=seed)
    w = ref.ternarize(ref.scale(_rand((k, n), seed=seed + 1)),
                      jnp.float32(0.02), jnp.float32(0.5))
    got = ternary_matmul(x, w)
    want = ref.ternary_matmul(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_mlp_shapes_exact():
    # the exact layer shapes used by the MLP artifacts
    for (m, k, n) in [(64, 784, 30), (64, 30, 20), (64, 20, 10)]:
        x = _rand((m, k), seed=m + k)
        w = _rand((k, n), seed=n)
        np.testing.assert_allclose(ternary_matmul(x, w),
                                   ref.ternary_matmul(x, w),
                                   rtol=1e-4, atol=1e-4)


def test_matmul_grads_match_dense():
    x = _rand((8, 33), seed=1)
    w = _rand((33, 9), seed=2)

    def f_pallas(x, w):
        return jnp.sum(ternary_matmul(x, w) ** 2)

    def f_dense(x, w):
        return jnp.sum((x @ w) ** 2)

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    hx, hw = jax.grad(f_dense, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, hx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, hw, rtol=1e-4, atol=1e-4)


def test_matmul_bf16():
    x = _rand((16, 100)).astype(jnp.bfloat16)
    w = _rand((100, 24)).astype(jnp.bfloat16)
    got = ternary_matmul(x, w).astype(np.float32)
    want = ref.ternary_matmul(x, w).astype(np.float32)
    np.testing.assert_allclose(got, want, **_tol(jnp.bfloat16))


def test_vmem_estimate_fits_tpu_budget():
    # default tiles must fit a 16 MB VMEM with double buffering headroom
    assert vmem_bytes_estimate(128, 128, 128) < 16 * 2**20 / 4


def test_mxu_utilization_estimates():
    assert mxu_utilization_estimate(128, 128, 128, bm=128, bn=128, bk=128) == 1.0
    assert 0 < mxu_utilization_estimate(64, 784, 30) < 1.0


# ---------------------------------------------------------------------------
# fttq_quantize (fused forward)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(r=SMALL, c=SMALL, seed=st.integers(0, 2**31),
       wq=st.floats(0.001, 2.0), t=st.floats(0.0, 1.0))
def test_fttq_quantize_matches_ref(r, c, seed, wq, t):
    th = _rand((r, c), seed=seed)
    qt, it, d = tk.fttq_quantize(th, jnp.float32(wq), t)
    qt2, it2, d2 = ref.fttq_quantize(th, jnp.float32(wq), t)
    np.testing.assert_allclose(qt, qt2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(it, it2, rtol=1e-6)
    np.testing.assert_allclose(d, d2, rtol=1e-5, atol=1e-7)


def test_fttq_zero_layer_is_stable():
    th = jnp.zeros((16, 16))
    qt, it, d = tk.fttq_quantize(th, 0.5, 0.05)
    assert np.all(np.isfinite(np.asarray(qt)))
    np.testing.assert_array_equal(np.asarray(qt), 0.0)
