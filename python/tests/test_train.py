"""L2 graph builders: shapes, masking semantics, learning progress.

These run the exact functions aot.py lowers, so passing here means the HLO
artifacts compute the right thing (the Rust side re-checks marshalling).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim, train
from compile.models import MODELS


def _toy_data(key, n, d, classes=10, batch=8, nb=4):
    """Linearly-separable-ish toy set shaped [nb, batch, d]."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (nb * batch, d))
    w_true = jax.random.normal(kw, (d, classes))
    y = jnp.argmax(x @ w_true, axis=1).astype(jnp.int32)
    return (x.reshape(nb, batch, d), y.reshape(nb, batch),
            jnp.ones((nb, batch), jnp.float32))


@pytest.fixture(scope="module")
def mlp():
    return MODELS["mlp"]


@pytest.fixture(scope="module")
def mlp_params(mlp):
    return mlp.init(jax.random.PRNGKey(42))


def test_fp_epoch_reduces_loss(mlp, mlp_params):
    opt = optim.make("sgd")
    fn, ins, outs = train.build_fp_train_epoch(mlp, opt, batch=16, nb=8)
    xs, ys, ms = _toy_data(jax.random.PRNGKey(0), 128, mlp.input_dim,
                           batch=16, nb=8)
    params = list(mlp_params)
    losses = []
    for _ in range(5):
        res = fn(*params, xs, ys, ms, jnp.float32(0.1))
        params = list(res[:len(params)])
        losses.append(float(res[-1]))
    assert losses[-1] < losses[0], losses


def test_fttq_epoch_reduces_loss(mlp, mlp_params):
    opt = optim.make("sgd")
    fn, ins, outs = train.build_fttq_train_epoch(mlp, opt, batch=16, nb=8)
    xs, ys, ms = _toy_data(jax.random.PRNGKey(1), 128, mlp.input_dim,
                           batch=16, nb=8)
    params = list(mlp_params)
    wq = jnp.full((3,), 0.05)
    losses = []
    for _ in range(5):
        res = fn(*params, wq, xs, ys, ms, jnp.float32(0.1))
        params = list(res[:6])
        wq = res[6]
        losses.append(float(res[-1]))
    assert losses[-1] < losses[0], losses
    assert np.all(np.isfinite(np.asarray(wq)))


def test_fttq_wq_actually_trains(mlp, mlp_params):
    opt = optim.make("sgd")
    fn, *_ = train.build_fttq_train_epoch(mlp, opt, batch=16, nb=4)
    xs, ys, ms = _toy_data(jax.random.PRNGKey(2), 64, mlp.input_dim,
                           batch=16, nb=4)
    wq0 = jnp.full((3,), 0.05)
    res = fn(*mlp_params, wq0, xs, ys, ms, jnp.float32(0.05))
    assert not np.allclose(np.asarray(res[6]), np.asarray(wq0))


def test_ttq_epoch_runs_and_tracks_factors(mlp, mlp_params):
    opt = optim.make("sgd")
    fn, *_ = train.build_ttq_train_epoch(mlp, opt, batch=16, nb=4)
    xs, ys, ms = _toy_data(jax.random.PRNGKey(3), 64, mlp.input_dim,
                           batch=16, nb=4)
    wp = jnp.full((3,), 0.05)
    wn = jnp.full((3,), 0.05)
    res = fn(*mlp_params, wp, wn, xs, ys, ms, jnp.float32(0.05))
    wp2, wn2 = res[6], res[7]
    assert wp2.shape == (3,) and wn2.shape == (3,)
    assert np.all(np.isfinite(np.asarray(wp2)))
    assert float(res[-1]) > 0


def test_mask_zero_batches_do_not_update(mlp, mlp_params):
    """Padding batches (mask all-zero) must leave params untouched."""
    opt = optim.make("sgd")
    fn, *_ = train.build_fp_train_epoch(mlp, opt, batch=8, nb=2)
    xs = jax.random.normal(jax.random.PRNGKey(4), (2, 8, mlp.input_dim))
    ys = jnp.zeros((2, 8), jnp.int32)
    ms = jnp.zeros((2, 8), jnp.float32)  # everything masked out
    res = fn(*mlp_params, xs, ys, ms, jnp.float32(0.5))
    for p0, p1 in zip(mlp_params, res[:6]):
        np.testing.assert_allclose(p0, p1, atol=1e-7)


def test_mask_partial_batch_matches_smaller_batch(mlp, mlp_params):
    """A half-masked batch must equal training on the half batch alone."""
    opt = optim.make("sgd")
    d = mlp.input_dim
    x8 = jax.random.normal(jax.random.PRNGKey(5), (8, d))
    y8 = jnp.arange(8, dtype=jnp.int32) % 10

    fn8, *_ = train.build_fp_train_epoch(mlp, opt, batch=8, nb=1)
    ms = jnp.array([[1, 1, 1, 1, 0, 0, 0, 0]], jnp.float32)
    res_masked = fn8(*mlp_params, x8[None], y8[None], ms, jnp.float32(0.1))

    fn4, *_ = train.build_fp_train_epoch(mlp, opt, batch=4, nb=1)
    res_small = fn4(*mlp_params, x8[:4][None], y8[:4][None],
                    jnp.ones((1, 4), jnp.float32), jnp.float32(0.1))
    for a, b in zip(res_masked[:6], res_small[:6]):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_eval_chunk_counts(mlp, mlp_params):
    fn, *_ = train.build_eval_chunk(mlp, batch=8, nb=3)
    xs, ys, ms = _toy_data(jax.random.PRNGKey(6), 24, mlp.input_dim,
                           batch=8, nb=3)
    ms = ms.at[2, 4:].set(0.0)  # mask out 4 samples
    loss_sum, correct, count = fn(*mlp_params, xs, ys, ms)
    assert float(count) == 20.0
    assert 0 <= float(correct) <= 20.0
    assert float(loss_sum) > 0


def test_eval_chunk_perfect_model(mlp):
    """A model wired to copy a one-hot input scores 100%."""
    fn, *_ = train.build_eval_chunk(mlp, batch=4, nb=1)
    params = mlp.init(jax.random.PRNGKey(7))
    xs = jnp.zeros((1, 4, mlp.input_dim))
    # route class k through feature k with huge weight
    w1 = jnp.zeros((mlp.input_dim, 30)).at[:10, :10].set(jnp.eye(10) * 100)
    w2 = jnp.zeros((30, 20)).at[:10, :10].set(jnp.eye(10) * 100)
    w3 = jnp.zeros((20, 10)).at[:10, :10].set(jnp.eye(10) * 100)
    params = [w1, params[1], w2, params[3], w3, params[5]]
    xs = xs.at[0, 0, 0].set(1.0).at[0, 1, 1].set(1.0)
    xs = xs.at[0, 2, 2].set(1.0).at[0, 3, 3].set(1.0)
    ys = jnp.array([[0, 1, 2, 3]], jnp.int32)
    ms = jnp.ones((1, 4), jnp.float32)
    _, correct, count = fn(*params, xs, ys, ms)
    assert float(correct) == 4.0 and float(count) == 4.0


def test_quantize_artifact_roundtrip(mlp, mlp_params):
    """quantize outputs: ternary patterns + per-layer deltas."""
    fn, ins, outs = train.build_quantize(mlp)
    assert [s["name"] for s in ins] == ["w1", "w2", "w3"]
    res = fn(mlp_params[0], mlp_params[2], mlp_params[4])
    its, deltas = res[:3], res[3:]
    for it, spec in zip(its, [(784, 30), (30, 20), (20, 10)]):
        assert it.shape == spec
        assert set(np.unique(np.asarray(it))).issubset({-1.0, 0.0, 1.0})
    for d in deltas:
        assert 0 < float(d) < 0.05 + 1e-6


def test_adam_cnn_epoch_runs():
    model = MODELS["resnetlite"]
    opt = optim.make("adam")
    params = model.init(jax.random.PRNGKey(8))
    fn, ins, outs = train.build_fttq_train_epoch(model, opt, batch=4, nb=2)
    wq = jnp.full((model.num_quantized(),), 0.05)
    opt_state = opt.init_state(params + [wq])
    xs = jax.random.normal(jax.random.PRNGKey(9), (2, 4, model.input_dim))
    ys = jnp.zeros((2, 4), jnp.int32)
    ms = jnp.ones((2, 4), jnp.float32)
    res = fn(*params, wq, *opt_state, xs, ys, ms, jnp.float32(0.002))
    assert len(res) == len(outs)
    assert np.isfinite(float(res[-1]))
    # Adam step counter advanced by nb
    assert float(res[-2]) == 2.0


def test_spec_lengths_match_fn_arity(mlp):
    opt = optim.make("sgd")
    for builder, extra in [
        (lambda: train.build_fp_train_epoch(mlp, opt, 8, 2), 0),
        (lambda: train.build_fttq_train_epoch(mlp, opt, 8, 2), 0),
        (lambda: train.build_ttq_train_epoch(mlp, opt, 8, 2), 0),
    ]:
        fn, ins, outs = builder()
        params = mlp.init(jax.random.PRNGKey(0))
        # build dummy args straight from the spec
        args = []
        for s in ins:
            dt = jnp.int32 if s.get("dtype") == "s32" else jnp.float32
            args.append(jnp.zeros(tuple(s["shape"]), dt))
        res = fn(*args)
        assert len(res) == len(outs)
