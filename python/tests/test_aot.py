"""aot.py manifest + HLO text consistency.

Emits a --quick artifact set into a tmpdir and checks the manifest is
self-consistent and the HLO text has the ENTRY signature the Rust runtime
expects (one parameter per manifest input, tupled outputs).
"""

import json
import math
import os
import re

import pytest

from compile import aot
from compile.models import MODELS


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.emit(out, models=["mlp"], quick=True, verbose=False)
    return out, manifest


def test_manifest_round_trips_json(emitted):
    out, manifest = emitted
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == json.loads(json.dumps(manifest))
    assert loaded["t_k"] == 0.05
    assert loaded["server_delta"] == 0.05
    assert loaded["wq_init"] == 0.05


def test_artifact_files_exist(emitted):
    out, manifest = emitted
    for name, art in manifest["artifacts"].items():
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100


def test_hlo_entry_parameter_count(emitted):
    """The ENTRY computation must declare one parameter per manifest input."""
    out, manifest = emitted
    for name, art in manifest["artifacts"].items():
        with open(os.path.join(out, art["file"])) as f:
            lines = f.read().splitlines()
        start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        body = []
        for l in lines[start + 1:]:
            if l.startswith("}"):
                break
            body.append(l)
        arity = sum(1 for l in body if re.search(r"= \S+ parameter\(\d+\)", l))
        assert arity == len(art["inputs"]), (name, arity, len(art["inputs"]))


def test_model_spec_matches_models_py(emitted):
    _, manifest = emitted
    m = manifest["models"]["mlp"]
    model = MODELS["mlp"]
    assert m["input_dim"] == model.input_dim
    assert m["num_quantized"] == model.num_quantized()
    names = [p["name"] for p in m["params"]]
    assert names == [s["name"] for s in model.spec()]
    total = sum(math.prod(p["shape"]) for p in m["params"])
    assert total == model.param_count() == 24380


def test_train_artifact_io_symmetry(emitted):
    """train outputs = inputs minus (xs, ys, ms, lr) plus mean_loss."""
    _, manifest = emitted
    for name, art in manifest["artifacts"].items():
        if art["kind"] != "train":
            continue
        in_names = [s["name"] for s in art["inputs"]]
        out_names = [s["name"] for s in art["outputs"]]
        assert in_names[-4:] == ["xs", "ys", "ms", "lr"]
        assert out_names[-1] == "mean_loss"
        assert in_names[:-4] == out_names[:-1], name
        for si, so in zip(art["inputs"][:-4], art["outputs"][:-1]):
            assert si["shape"] == so["shape"], (name, si, so)


def test_batch_plan_covers_fig7():
    """Fig. 7 sweeps local batch size; the plan must include >=3 sizes."""
    assert len(aot.MODEL_PLAN["mlp"]["train_batches"]) >= 3
    for b, nb in aot.MODEL_PLAN["mlp"]["train_batches"].items():
        assert b * nb == 1024  # constant chunk size across the sweep
