//! A real T-FedAvg federation over TCP on localhost, cross-checked against
//! the in-process loopback transport.
//!
//!     cargo run --release --example tcp_round
//!
//! The coordinator binds an ephemeral port; four clients dial in over
//! real sockets and answer round assignments — the exact code path the
//! `tfed serve` / `tfed client` subcommands run across processes. The same
//! experiment is then repeated over loopback: final global parameters and
//! frame-layer byte counts must match bit-for-bit, demonstrating that the
//! Table-IV communication numbers are transport-independent.

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::availability::AvailabilityModel;
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::{materialize_data, Orchestrator};
use tfed::coordinator::ClientRuntime;
use tfed::eval::RunMetrics;
use tfed::model::ParamSet;
use tfed::transport::{TcpBinding, TcpClient};

fn main() -> anyhow::Result<()> {
    tfed::util::logging::set_level(tfed::util::logging::Level::Warn);
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 11);
    cfg.n_clients = 4;
    cfg.rounds = 3;
    cfg.local_epochs = 2;
    cfg.train_samples = 800;
    cfg.test_samples = 300;
    cfg.batch = 16;
    cfg.lr = 0.1;
    cfg.native_backend = true;
    let backend = make_backend(None, "mlp", cfg.batch, true)?;

    println!("== T-FedAvg over TCP (localhost) ==");
    println!("{}", cfg.summary());

    // --- the TCP federation -------------------------------------------------
    let binding = TcpBinding::bind("127.0.0.1:0")?;
    let addr = binding.local_addr()?;
    println!("coordinator listening on {addr}");
    let (shards, _test) = materialize_data(&cfg, backend.schema().input_dim)?;

    let (tcp_metrics, tcp_global): (RunMetrics, ParamSet) =
        std::thread::scope(|s| -> anyhow::Result<(RunMetrics, ParamSet)> {
            // each thread stands in for one `tfed client` process: same
            // handshake, same frames, same sockets
            for (cid, shard) in shards.into_iter().enumerate() {
                let backend = backend.as_ref();
                s.spawn(move || {
                    let (mut client, got_cfg) =
                        TcpClient::connect(&addr.to_string(), cid as u32).expect("connect");
                    let runtime = ClientRuntime {
                        client_id: cid as u32,
                        backend,
                        shard,
                        local_epochs: got_cfg.local_epochs,
                        lr: got_cfg.lr,
                        codec: got_cfg.codec,
                    };
                    let rounds = client.serve(&runtime).expect("serve");
                    println!(
                        "  client {cid}: {rounds} rounds, up {} B down {} B",
                        client.stats.up_bytes, client.stats.down_bytes
                    );
                });
            }
            let transport = binding.accept_clients(cfg.n_clients, &cfg)?;
            let mut orch = Orchestrator::with_transport(
                cfg.clone(),
                backend.as_ref(),
                AvailabilityModel::always_on(),
                Box::new(transport),
            )?;
            // always release the waiting clients, even when the run fails —
            // otherwise the error surfaces as client-thread panics instead
            let run_result = orch.run();
            orch.shutdown_transport()?;
            run_result?;
            Ok((orch.metrics.clone(), orch.global().clone()))
        })?;

    // --- the same run over the in-process loopback transport ----------------
    let mut lb = Orchestrator::new(cfg.clone(), backend.as_ref())?;
    lb.run()?;

    println!();
    println!(
        "{:>5} {:>10} {:>12} {:>12} {:>8}",
        "round", "acc(tcp)", "up tcp (B)", "up loop (B)", "equal"
    );
    let mut all_equal = true;
    for (t, l) in tcp_metrics.records.iter().zip(&lb.metrics.records) {
        let equal = t.up_bytes == l.up_bytes
            && t.down_bytes == l.down_bytes
            && t.test_acc.to_bits() == l.test_acc.to_bits();
        all_equal &= equal;
        println!(
            "{:>5} {:>10.4} {:>12} {:>12} {:>8}",
            t.round, t.test_acc, t.up_bytes, l.up_bytes, equal
        );
    }
    let drift = tcp_global.l2_distance(lb.global());
    println!();
    println!("global model L2(tcp, loopback) = {drift}");
    println!(
        "totals: up {} B / down {} B over TCP, {} data frames each way",
        tcp_metrics.total_up_bytes(),
        tcp_metrics.total_down_bytes(),
        tcp_metrics.total_up_frames(),
    );
    anyhow::ensure!(all_equal && drift == 0.0, "tcp and loopback runs diverged");
    println!("tcp == loopback: byte counts and final parameters identical");
    Ok(())
}
