//! Non-IID federation: sweep Nc (classes per client) and compare FedAvg vs
//! T-FedAvg — the paper's §V-C experiment at example scale.
//!
//!     cargo run --release --example non_iid_clients

use std::sync::Arc;

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::run_experiment;
use tfed::runtime::manifest::default_artifacts_dir;
use tfed::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let have_artifacts = default_artifacts_dir().join("manifest.json").exists();
    let engine = if have_artifacts {
        Some(Arc::new(Engine::load(default_artifacts_dir())?))
    } else {
        eprintln!("artifacts/ missing -> native backend");
        None
    };

    println!("== non-IID sweep (Nc = classes per client) ==");
    println!("{:>4} {:>12} {:>12}", "Nc", "FedAvg", "T-FedAvg");
    for nc in [2usize, 5, 10] {
        let mut row = Vec::new();
        for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
            let mut cfg = ExperimentConfig::table2(protocol, Task::MnistLike, 11);
            cfg.nc = nc;
            cfg.rounds = 12;
            cfg.train_samples = 4_000;
            cfg.test_samples = 1_000;
            cfg.native_backend = engine.is_none();
            let backend =
                make_backend(engine.clone(), "mlp", cfg.batch, engine.is_none())?;
            let m = run_experiment(cfg, backend.as_ref())?;
            row.push(m.best_acc());
        }
        println!("{:>4} {:>12.4} {:>12.4}", nc, row[0], row[1]);
    }
    println!();
    println!("expected shape (paper Fig. 8): accuracy degrades as Nc shrinks;");
    println!("T-FedAvg tracks FedAvg within noise at every Nc.");
    Ok(())
}
