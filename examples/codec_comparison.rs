//! The compression-comparison platform in one screen: the same federated
//! experiment under every registered payload codec, with wire bytes
//! measured at the transport frame layer (not estimated).
//!
//!     cargo run --release --example codec_comparison
//!
//! T-FedAvg/ternary is the paper's protocol; the FedAvg rows reproduce the
//! competing codec families — STC top-k sparsification (Sattler et al.),
//! stochastic k-bit quantization, and the fp16/dense baselines — under
//! identical data, model, seed, and measurement harness.

use tfed::compress::CodecSpec;
use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::Orchestrator;
use tfed::eval::mb;

fn cfg_for(codec: &str) -> anyhow::Result<ExperimentConfig> {
    let spec = CodecSpec::parse(codec)?;
    let mut cfg = ExperimentConfig::table2(Protocol::for_codec(spec), Task::MnistLike, 42);
    cfg.codec = spec;
    cfg.n_clients = 4;
    cfg.rounds = 5;
    cfg.local_epochs = 2;
    cfg.train_samples = 1_200;
    cfg.test_samples = 400;
    cfg.batch = 16;
    cfg.lr = 0.15;
    cfg.native_backend = true;
    cfg.validate()?;
    Ok(cfg)
}

fn main() -> anyhow::Result<()> {
    tfed::util::logging::set_level(tfed::util::logging::Level::Warn);
    println!("== payload codecs, identical experiment (measured wire bytes) ==");
    println!(
        "{:<12} {:<10} {:>9} {:>12} {:>12} {:>9}",
        "codec", "protocol", "best_acc", "up (MB)", "down (MB)", "vs dense"
    );

    let mut dense_total = None;
    for codec in ["dense", "fp16", "quant8", "quant4", "quant1", "stc:k=0.01", "ternary"] {
        let cfg = cfg_for(codec)?;
        let protocol = cfg.protocol;
        let backend = make_backend(None, "mlp", cfg.batch, true)?;
        let mut orch = Orchestrator::new(cfg, backend.as_ref())?;
        orch.run()?;
        let m = &orch.metrics;
        let total = m.total_up_bytes() + m.total_down_bytes();
        let dense = *dense_total.get_or_insert(total);
        println!(
            "{:<12} {:<10} {:>8.2}% {:>12.3} {:>12.3} {:>8.1}x",
            codec,
            protocol.name(),
            m.best_acc() * 100.0,
            mb(m.total_up_bytes()),
            mb(m.total_down_bytes()),
            dense as f64 / total as f64
        );
    }
    println!();
    println!("ternary rides the full T-FedAvg protocol (FTTQ local training);");
    println!("the other codecs compress FedAvg payloads in both directions.");
    Ok(())
}
