//! Communication-budget planning: the paper's §VI argument made concrete —
//! under a fixed byte budget, T-FedAvg affords ~16x more rounds than
//! FedAvg, which converts into accuracy.
//!
//!     cargo run --release --example comm_budget

use std::sync::Arc;

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::Orchestrator;
use tfed::eval::mb;
use tfed::runtime::manifest::default_artifacts_dir;
use tfed::runtime::Engine;

/// Run until the up+down byte budget is exhausted (or max_rounds).
fn run_with_budget(
    mut cfg: ExperimentConfig,
    engine: Option<Arc<Engine>>,
    budget_bytes: u64,
    max_rounds: usize,
) -> anyhow::Result<(usize, f32, u64)> {
    cfg.rounds = max_rounds;
    let native = engine.is_none();
    cfg.native_backend = native;
    let backend = make_backend(engine, "mlp", cfg.batch, native)?;
    let mut orch = Orchestrator::new(cfg, backend.as_ref())?;
    let mut spent = 0u64;
    let mut rounds = 0;
    for r in 1..=max_rounds {
        let rec = orch.round(r)?;
        spent += rec.up_bytes + rec.down_bytes;
        rounds = r;
        if spent >= budget_bytes {
            break;
        }
    }
    Ok((rounds, orch.metrics.best_acc(), spent))
}

fn main() -> anyhow::Result<()> {
    let engine = if default_artifacts_dir().join("manifest.json").exists() {
        Some(Arc::new(Engine::load(default_artifacts_dir())?))
    } else {
        eprintln!("artifacts/ missing -> native backend");
        None
    };

    let budget: u64 = 6 * 1024 * 1024; // 6 MB of total traffic
    println!("== fixed communication budget: {:.1} MB ==", mb(budget));
    println!(
        "{:>10} {:>8} {:>10} {:>12}",
        "protocol", "rounds", "best_acc", "spent (MB)"
    );
    for protocol in [Protocol::FedAvg, Protocol::TFedAvg] {
        let mut cfg = ExperimentConfig::table2(protocol, Task::MnistLike, 23);
        cfg.train_samples = 4_000;
        cfg.test_samples = 1_000;
        let (rounds, acc, spent) =
            run_with_budget(cfg, engine.clone(), budget, 60)?;
        println!(
            "{:>10} {:>8} {:>10.4} {:>12.2}",
            protocol.name(),
            rounds,
            acc,
            mb(spent)
        );
    }
    println!();
    println!("T-FedAvg stretches the same budget across ~16x more rounds");
    println!("(paper §VI: more rounds/clients within the same constraint).");
    Ok(())
}
