//! The paper's motivating scenario (Fig. 2): branch factories with very
//! different data volumes (unbalanced beta, eq. 29) and flaky connectivity
//! (client dropout), training a shared model with T-FedAvg.
//!
//!     cargo run --release --example unbalanced_factories

use std::sync::Arc;

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::{FaultSpec, Orchestrator};
use tfed::runtime::manifest::default_artifacts_dir;
use tfed::runtime::Engine;
use tfed::util::stats;

fn main() -> anyhow::Result<()> {
    let engine = if default_artifacts_dir().join("manifest.json").exists() {
        Some(Arc::new(Engine::load(default_artifacts_dir())?))
    } else {
        eprintln!("artifacts/ missing -> native backend");
        None
    };

    println!("== unbalanced factories (beta sweep + 20% dropout) ==");
    println!(
        "{:>6} {:>14} {:>10} {:>10}",
        "beta", "shard sizes", "meas.beta", "best_acc"
    );
    for beta in [0.1, 0.4, 1.0] {
        let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 31);
        cfg.n_clients = 8;
        cfg.beta = beta;
        cfg.rounds = 12;
        cfg.train_samples = 4_000;
        cfg.test_samples = 1_000;
        cfg.native_backend = engine.is_none();
        let backend =
            make_backend(engine.clone(), "mlp", cfg.batch, engine.is_none())?;
        let mut orch = Orchestrator::with_faults(
            cfg,
            backend.as_ref(),
            FaultSpec { client_dropout: 0.2 },
        )?;
        let sizes = orch.shard_sizes();
        let measured = stats::unbalancedness(&sizes);
        orch.run()?;
        let sizes_str = format!("{}..{}", sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        println!(
            "{:>6.1} {:>14} {:>10.3} {:>10.4}",
            beta,
            sizes_str,
            measured,
            orch.metrics.best_acc()
        );
    }
    println!();
    println!("expected shape (paper Fig. 11): accuracy is flat in beta —");
    println!("unbalanced data sizes alone do not hurt federated training.");
    Ok(())
}
