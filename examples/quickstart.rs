//! Quickstart: train a federated MLP with T-FedAvg on the MNIST-like task
//! and print the learning curve + communication costs.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Uses the PJRT backend when `artifacts/` is built, otherwise falls back
//! to the pure-Rust native backend so the example always runs.

use std::sync::Arc;

use tfed::config::{ExperimentConfig, Protocol, Task};
use tfed::coordinator::backend::make_backend;
use tfed::coordinator::server::Orchestrator;
use tfed::eval::mb;
use tfed::runtime::manifest::default_artifacts_dir;
use tfed::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::table2(Protocol::TFedAvg, Task::MnistLike, 7);
    cfg.rounds = 15;
    cfg.train_samples = 4_000;
    cfg.test_samples = 1_000;

    let have_artifacts = default_artifacts_dir().join("manifest.json").exists();
    let backend = if have_artifacts {
        let engine = Arc::new(Engine::load(default_artifacts_dir())?);
        make_backend(Some(engine), "mlp", cfg.batch, false)?
    } else {
        eprintln!("artifacts/ missing -> native backend (run `make artifacts` for PJRT)");
        cfg.native_backend = true;
        make_backend(None, "mlp", cfg.batch, true)?
    };

    println!("== T-FedAvg quickstart ==");
    println!("{}", cfg.summary());
    println!();
    println!("{:>5} {:>12} {:>10} {:>12} {:>12}", "round", "train_loss", "test_acc", "up (KB)", "down (KB)");

    let mut orch = Orchestrator::new(cfg, backend.as_ref())?;
    for r in 1..=orch.cfg.rounds {
        let rec = orch.round(r)?;
        println!(
            "{:>5} {:>12.4} {:>10.4} {:>12.1} {:>12.1}",
            rec.round,
            rec.train_loss,
            rec.test_acc,
            rec.up_bytes as f64 / 1024.0,
            rec.down_bytes as f64 / 1024.0
        );
    }

    let m = &orch.metrics;
    println!();
    println!("final accuracy : {:.4}", m.final_acc());
    println!("best accuracy  : {:.4}", m.best_acc());
    println!("total upstream : {:.2} MB", mb(m.total_up_bytes()));
    println!("total downstream: {:.2} MB", mb(m.total_down_bytes()));
    println!(
        "(FedAvg would have moved ~16x more: {:.2} MB each way)",
        mb(m.total_up_bytes() * 16)
    );
    Ok(())
}
